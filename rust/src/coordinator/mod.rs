//! The RedSync coordinator: data-parallel training with residual gradient
//! compression over the in-process fabric — the paper's system
//! contribution, as the L3 layer of the stack.
//!
//! [`Trainer`] spawns one worker thread per rank (the paper's
//! one-process-per-GPU deployment); each worker owns a PJRT runtime and a
//! model replica, executes forward/backward through the AOT artifacts and
//! synchronizes per-layer by the §5.5 policy: dense allreduce for small
//! layers, sparse allgather of compressed residuals (Alg. 4/5) otherwise.
//! Bucket synchronization runs through a [`crate::pipeline::SyncEngine`]
//! — inline (`Sequential`, the default/oracle) or overlapped on a comm
//! thread pool (`Pipelined`, `cfg.pipeline`); both are bit-identical by
//! construction and by test.

pub mod checkpoint;
pub mod metrics;
pub mod worker;

pub use checkpoint::{Checkpoint, LayerState};
pub use metrics::{TrainReport, WorkerResult};

use crate::collectives::transport::TrafficStats;
use crate::collectives::{allgather, LocalFabric, Transport};
use crate::config::TrainConfig;
use crate::models::schema::{Manifest, ModelSchema};
use crate::util::timer::PhaseTimer;
use std::thread;
use std::time::Instant;

#[derive(Debug)]
pub enum TrainError {
    UnknownModel(String),
    Config(crate::config::ConfigError),
    Worker(String),
    Panic,
}

impl std::fmt::Display for TrainError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TrainError::UnknownModel(m) => write!(f, "unknown model '{m}' (run `make artifacts`?)"),
            TrainError::Config(e) => write!(f, "config: {e}"),
            TrainError::Worker(msg) => write!(f, "worker failed: {msg}"),
            TrainError::Panic => write!(f, "worker panicked"),
        }
    }
}

impl std::error::Error for TrainError {}

impl From<crate::config::ConfigError> for TrainError {
    fn from(e: crate::config::ConfigError) -> Self {
        TrainError::Config(e)
    }
}

/// Data-parallel trainer: resolves the model schema, spawns the worker
/// fleet and aggregates the run report.
pub struct Trainer {
    pub cfg: TrainConfig,
    pub schema: ModelSchema,
}

impl Trainer {
    pub fn new(manifest: &Manifest, cfg: TrainConfig) -> Result<Trainer, TrainError> {
        cfg.validate()?;
        let schema = manifest
            .models
            .get(&cfg.model)
            .cloned()
            .ok_or_else(|| TrainError::UnknownModel(cfg.model.clone()))?;
        Ok(Trainer { cfg, schema })
    }

    /// Run the full training job; blocks until all workers finish.
    /// Elastic runs (`cfg.elastic.enabled`) go through the
    /// membership-aware fleet instead (`crate::elastic`): injected
    /// kills/stalls, reshapes and rejoins are survived rather than
    /// fatal.
    pub fn run(&self) -> Result<TrainReport, TrainError> {
        if self.cfg.elastic.enabled {
            return self.run_elastic();
        }
        let world = self.cfg.world;
        let mut fabric = LocalFabric::new(world);
        let stats = std::sync::Arc::clone(&fabric.stats);
        let start = Instant::now();

        let results: Vec<WorkerResult> = thread::scope(|s| {
            let handles: Vec<_> = fabric
                .take_all()
                .into_iter()
                .map(|t| {
                    let cfg = &self.cfg;
                    let schema = &self.schema;
                    s.spawn(move || worker::run_worker(cfg, schema, &t))
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().map_err(|_| TrainError::Panic)?.map_err(TrainError::Worker))
                .collect::<Result<Vec<_>, TrainError>>()
        })?;
        let wall_secs = start.elapsed().as_secs_f64();

        let mut phases = PhaseTimer::new();
        let mut mux_bytes = 0u64;
        let mut mux_ctrl_bytes = 0u64;
        for r in &results {
            phases.merge(&r.timer);
            mux_bytes += r.mux_bytes;
            mux_ctrl_bytes += r.mux_ctrl_bytes;
        }
        let h0 = results[0].param_hash;
        let replicas_consistent = results.iter().all(|r| r.param_hash == h0);
        let link_traffic =
            metrics::merge_link_traffic(results.iter().map(|r| r.link_traffic.clone()));
        let span_drops: u64 = results.iter().map(|r| r.span_drops).sum();
        let rank0 = results
            .into_iter()
            .find(|r| r.rank == 0)
            .expect("rank 0 result");

        Ok(TrainReport {
            model: self.cfg.model.clone(),
            world,
            steps: self.cfg.steps,
            strategy: self.cfg.strategy.label(),
            final_loss: rank0.final_loss,
            final_eval: rank0.eval_curve.last().map(|&(_, e)| e),
            loss_curve: rank0.loss_curve,
            eval_curve: rank0.eval_curve,
            union_density: rank0.union_density,
            sent_density: rank0.sent_density,
            phases,
            bytes: stats.bytes(),
            messages: stats.message_count(),
            mux_bytes,
            mux_ctrl_bytes,
            wall_secs,
            replicas_consistent,
            membership: rank0.membership,
            status_note: None,
            step_p50_us: rank0.step_p50_us,
            step_p99_us: rank0.step_p99_us,
            rank_skew: rank0.rank_skew,
            simd_backend: rank0.simd_backend,
            link_traffic,
            rejoin: rank0.rejoin,
            repo: rank0.repo,
            span_drops,
            calib: rank0.calib,
        })
    }

    /// The elastic in-process fleet: fabric generations (shrink in
    /// place, rejoin via a fresh full-world generation) orchestrated by
    /// [`crate::elastic::run_local_fleet`], with the real PJRT model
    /// behind the driver's `Workload`.
    fn run_elastic(&self) -> Result<TrainReport, TrainError> {
        use crate::elastic::ElasticStatus;
        let cfg = &self.cfg;
        let schema = &self.schema;
        let world = cfg.world;
        let specs = worker::elastic_specs(cfg, schema);
        let opts = worker::elastic_opts(cfg);
        let fleet = crate::elastic::run_local_fleet(
            world,
            &specs,
            &opts,
            |rank| worker::elastic_init(cfg, schema, &specs, rank),
            |_rank| worker::ModelWorkload::new(cfg, schema),
        )
        .map_err(TrainError::Worker)?;

        let finished: Vec<usize> = (0..world)
            .filter(|&r| fleet.ranks[r].status == ElasticStatus::Finished)
            .collect();
        if finished.is_empty() {
            return Err(TrainError::Worker(
                "no rank survived to the end of the elastic run".into(),
            ));
        }
        let replicas_consistent =
            finished.iter().all(|&r| fleet.ranks[r].replicas_consistent);
        // the view leader (group-local rank 0) records the loss curve,
        // and the leader itself can be a casualty — merge every rank's
        // curve, finished ranks first (their post-rollback values are
        // the canonical trajectory; a dead leader only fills in steps
        // nobody else logged)
        let mut curve: std::collections::BTreeMap<usize, f32> = std::collections::BTreeMap::new();
        for &r in &finished {
            for &(s, l) in &fleet.ranks[r].loss_curve {
                curve.entry(s).or_insert(l);
            }
        }
        for o in &fleet.ranks {
            for &(s, l) in &o.loss_curve {
                curve.entry(s).or_insert(l);
            }
        }
        let loss_curve: Vec<(usize, f32)> = curve.into_iter().collect();
        let reporter = finished
            .iter()
            .copied()
            .max_by_key(|&r| fleet.ranks[r].loss_curve.len())
            .expect("nonempty");
        let mut phases = PhaseTimer::new();
        let mut mux_bytes = 0u64;
        let mut mux_ctrl_bytes = 0u64;
        let mut rejoin = metrics::RejoinStats::default();
        let mut repo = metrics::RepoStats::default();
        for o in &fleet.ranks {
            phases.merge(&o.timer);
            mux_bytes += o.mux_words * 4;
            mux_ctrl_bytes += o.ctrl_words * 4;
            rejoin.absorb(&o.rejoin);
            repo.absorb(&o.repo);
        }
        let lead = &fleet.ranks[reporter];
        Ok(TrainReport {
            model: cfg.model.clone(),
            world,
            steps: cfg.steps,
            strategy: cfg.strategy.label(),
            final_loss: lead.final_loss,
            final_eval: None,
            loss_curve,
            eval_curve: Vec::new(),
            union_density: Vec::new(),
            sent_density: Vec::new(),
            phases,
            bytes: fleet.bytes,
            messages: fleet.messages,
            mux_bytes,
            mux_ctrl_bytes,
            wall_secs: fleet.wall_secs,
            replicas_consistent,
            membership: lead.events.clone(),
            status_note: None,
            step_p50_us: 0,
            step_p99_us: 0,
            rank_skew: 0.0,
            simd_backend: crate::compression::simd::active().name(),
            link_traffic: Vec::new(),
            rejoin,
            repo,
            span_drops: 0,
            calib: Default::default(),
        })
    }
}

impl Trainer {
    /// Run *this process's* rank of a distributed job over an
    /// already-connected transport (e.g. `net::TcpTransport`) — the
    /// multi-process counterpart of [`Trainer::run`], which owns all
    /// ranks as threads.
    ///
    /// After the worker loop the ranks allgather their parameter hashes,
    /// so every process learns `replicas_consistent` — the same replica
    /// drift check `run` performs centrally.  `stats` are this fabric's
    /// traffic counters (per-process for TCP), if the caller has them.
    pub fn run_rank<T: Transport + Sync>(
        &self,
        transport: &T,
        stats: Option<&TrafficStats>,
    ) -> Result<TrainReport, TrainError> {
        if self.cfg.elastic.enabled {
            return self.run_rank_elastic(transport, stats);
        }
        let start = Instant::now();
        let result = worker::run_worker(&self.cfg, &self.schema, transport)
            .map_err(TrainError::Worker)?;
        let wall_secs = start.elapsed().as_secs_f64();

        let h = result.param_hash;
        let hashes = allgather(transport, vec![(h & 0xFFFF_FFFF) as u32, (h >> 32) as u32]);
        let replicas_consistent = hashes
            .iter()
            .all(|w| w.len() == 2 && (w[0] as u64 | (w[1] as u64) << 32) == h);

        Ok(TrainReport {
            model: self.cfg.model.clone(),
            world: self.cfg.world,
            steps: self.cfg.steps,
            strategy: self.cfg.strategy.label(),
            final_loss: result.final_loss,
            final_eval: result.eval_curve.last().map(|&(_, e)| e),
            loss_curve: result.loss_curve,
            eval_curve: result.eval_curve,
            union_density: result.union_density,
            sent_density: result.sent_density,
            phases: result.timer,
            bytes: stats.map_or(0, |s| s.bytes()),
            messages: stats.map_or(0, |s| s.message_count()),
            mux_bytes: result.mux_bytes,
            mux_ctrl_bytes: result.mux_ctrl_bytes,
            wall_secs,
            replicas_consistent,
            membership: result.membership,
            status_note: None,
            step_p50_us: result.step_p50_us,
            step_p99_us: result.step_p99_us,
            rank_skew: result.rank_skew,
            simd_backend: result.simd_backend,
            link_traffic: result.link_traffic,
            rejoin: result.rejoin,
            repo: result.repo,
            span_drops: result.span_drops,
            calib: result.calib,
        })
    }

    /// One elastic rank over an external transport (`redsync launch`
    /// with `--elastic`): the view's consistency verdict comes from the
    /// driver's final in-view hash exchange.  A killed or evicted rank
    /// reports its partial run with an explicit `status_note` (the
    /// launcher treats that as a clean exit without claiming replica
    /// consistency).
    fn run_rank_elastic<T: Transport + Sync>(
        &self,
        transport: &T,
        stats: Option<&TrafficStats>,
    ) -> Result<TrainReport, TrainError> {
        use crate::elastic::ElasticStatus;
        let start = Instant::now();
        let (result, out) =
            worker::run_worker_elastic(&self.cfg, &self.schema, transport)
                .map_err(TrainError::Worker)?;
        let wall_secs = start.elapsed().as_secs_f64();
        let status_note = match out.status {
            ElasticStatus::Finished => None,
            ElasticStatus::Killed => {
                Some(format!("killed by fault injection at step {}", out.state.step))
            }
            ElasticStatus::Evicted => {
                Some(format!("evicted from the view at epoch {}", out.epoch))
            }
            ElasticStatus::Paused => Some("paused at a rejoin barrier".into()),
        };
        Ok(TrainReport {
            model: self.cfg.model.clone(),
            world: self.cfg.world,
            steps: self.cfg.steps,
            strategy: self.cfg.strategy.label(),
            final_loss: result.final_loss,
            final_eval: None,
            loss_curve: result.loss_curve,
            eval_curve: Vec::new(),
            union_density: Vec::new(),
            sent_density: Vec::new(),
            phases: result.timer,
            bytes: stats.map_or(0, |s| s.bytes()),
            messages: stats.map_or(0, |s| s.message_count()),
            mux_bytes: result.mux_bytes,
            mux_ctrl_bytes: result.mux_ctrl_bytes,
            wall_secs,
            replicas_consistent: out.replicas_consistent,
            membership: result.membership,
            status_note,
            step_p50_us: 0,
            step_p99_us: 0,
            rank_skew: 0.0,
            simd_backend: result.simd_backend,
            link_traffic: result.link_traffic,
            rejoin: result.rejoin,
            repo: result.repo,
            span_drops: result.span_drops,
            calib: Default::default(),
        })
    }
}

/// Convenience: run a config against the default artifact directory.
pub fn train(cfg: TrainConfig) -> Result<TrainReport, TrainError> {
    let manifest = Manifest::load(Manifest::default_dir())
        .map_err(|e| TrainError::Worker(format!("manifest: {e}")))?;
    Trainer::new(&manifest, cfg)?.run()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::proxy_thresholds;
    use crate::simnet::iteration::Strategy;
    use std::path::PathBuf;

    fn manifest() -> Option<Manifest> {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: run `make artifacts`");
            return None;
        }
        Some(Manifest::load(dir).unwrap())
    }

    fn smoke_cfg(strategy: Strategy) -> TrainConfig {
        TrainConfig {
            model: "lm_tiny".into(),
            world: 2,
            steps: 8,
            strategy,
            density: 0.05,
            thresholds: crate::compression::PolicyThresholds { thsd1: 512, thsd2: 8 * 1024 },
            log_every: 2,
            eval_every: 4,
            ..TrainConfig::default()
        }
    }

    #[test]
    fn dense_baseline_trains_and_replicas_agree() {
        let Some(m) = manifest() else { return };
        let r = Trainer::new(&m, smoke_cfg(Strategy::Dense)).unwrap().run().unwrap();
        assert!(r.replicas_consistent);
        assert!(r.final_loss.is_finite());
        assert!(!r.loss_curve.is_empty());
        // dense: all traffic through allreduce, no sparse phases
        assert_eq!(r.phases.total(metrics::phase::SELECT), 0.0);
        assert!(r.phases.total(metrics::phase::COMM_DENSE) > 0.0);
    }

    #[test]
    fn rgc_trains_replicas_agree_and_loss_drops() {
        let Some(m) = manifest() else { return };
        let mut cfg = smoke_cfg(Strategy::Rgc);
        cfg.steps = 30;
        cfg.lr = crate::optim::LrSchedule::Constant { lr: 0.3 };
        let r = Trainer::new(&m, cfg).unwrap().run().unwrap();
        assert!(r.replicas_consistent, "replica drift under RGC");
        let first = r.loss_curve.first().unwrap().1;
        let last = r.loss_curve.last().unwrap().1;
        assert!(last < first, "loss did not drop: {first} -> {last}");
        assert!(r.phases.total(metrics::phase::SELECT) > 0.0);
        assert!(r.phases.total(metrics::phase::COMM_SPARSE) > 0.0);
    }

    #[test]
    fn quant_rgc_trains() {
        let Some(m) = manifest() else { return };
        let r = Trainer::new(&m, smoke_cfg(Strategy::QuantRgc)).unwrap().run().unwrap();
        assert!(r.replicas_consistent);
        assert!(r.final_loss.is_finite());
    }

    #[test]
    fn rgc_moves_less_traffic_than_dense() {
        let Some(m) = manifest() else { return };
        let mut dense_cfg = smoke_cfg(Strategy::Dense);
        let mut rgc_cfg = smoke_cfg(Strategy::Rgc);
        dense_cfg.eval_every = 0;
        rgc_cfg.eval_every = 0;
        rgc_cfg.density = 0.01;
        let dense = Trainer::new(&m, dense_cfg).unwrap().run().unwrap();
        let rgc = Trainer::new(&m, rgc_cfg).unwrap().run().unwrap();
        assert!(
            (rgc.bytes as f64) < 0.7 * dense.bytes as f64,
            "rgc {} !< dense {}",
            rgc.bytes,
            dense.bytes
        );
    }

    #[test]
    fn unknown_model_rejected() {
        let Some(m) = manifest() else { return };
        let cfg = TrainConfig { model: "nope".into(), ..TrainConfig::default() };
        assert!(matches!(Trainer::new(&m, cfg), Err(TrainError::UnknownModel(_))));
    }

    #[test]
    fn mlp_accuracy_improves_under_rgc() {
        let Some(m) = manifest() else { return };
        let cfg = TrainConfig {
            model: "mlp_tiny".into(),
            world: 2,
            steps: 80,
            strategy: Strategy::Rgc,
            density: 0.05,
            thresholds: crate::compression::PolicyThresholds { thsd1: 256, thsd2: 4 * 1024 },
            optimizer: crate::optim::Optimizer::Nesterov { momentum: 0.9 },
            lr: crate::optim::LrSchedule::Constant { lr: 0.1 },
            log_every: 20,
            eval_every: 79,
            ..TrainConfig::default()
        };
        let r = Trainer::new(&m, cfg).unwrap().run().unwrap();
        assert!(r.replicas_consistent);
        let acc = r.final_eval.unwrap();
        assert!(acc > 0.5, "accuracy {acc}");
    }

    #[test]
    fn elastic_no_fault_matches_the_plain_trainer() {
        // the elastic stack (heartbeats, snapshots, group-scoped
        // collectives) must not change the math: without faults its
        // loss trajectory is bit-identical to the fail-fast trainer's
        let Some(m) = manifest() else { return };
        let mut cfg = smoke_cfg(Strategy::Rgc);
        cfg.eval_every = 0;
        let plain = Trainer::new(&m, cfg.clone()).unwrap().run().unwrap();
        cfg.elastic.enabled = true;
        let elastic = Trainer::new(&m, cfg).unwrap().run().unwrap();
        assert!(elastic.replicas_consistent);
        assert!(elastic.membership.is_empty(), "no faults, no events");
        assert_eq!(plain.loss_curve, elastic.loss_curve, "elastic changed the math");
    }

    #[test]
    fn elastic_run_survives_an_injected_kill() {
        use crate::elastic::FaultSpec;
        let Some(m) = manifest() else { return };
        let mut cfg = smoke_cfg(Strategy::Rgc);
        cfg.eval_every = 0;
        cfg.steps = 10;
        cfg.elastic.enabled = true;
        cfg.elastic.kill = vec![FaultSpec { rank: 1, step: 5 }];
        let r = Trainer::new(&m, cfg).unwrap().run().unwrap();
        assert!(r.replicas_consistent, "survivor must finish consistent");
        assert_eq!(r.membership.len(), 1, "{:?}", r.membership);
        assert_eq!(r.membership[0].lost, vec![1]);
        assert_eq!(r.membership[0].world_after, 1);
        assert!(r.summary().contains("membership events"));
    }

    #[test]
    fn warmup_dense_epochs_reduce_select_time() {
        let Some(m) = manifest() else { return };
        let mut cfg = smoke_cfg(Strategy::Rgc);
        cfg.eval_every = 0;
        cfg.steps = 8;
        cfg.steps_per_epoch = 4;
        cfg.warmup = crate::config::WarmupKind::DenseEpochs(2);
        // entire run inside warm-up: no sparse sync at all
        let r = Trainer::new(&m, cfg).unwrap().run().unwrap();
        assert_eq!(r.phases.total(metrics::phase::SELECT), 0.0);
        let _ = proxy_thresholds();
    }
}
