//! Checkpointing: serialize the full training state — parameters,
//! per-layer residuals and momentum buffers, optimizer velocity and the
//! step counter — so a run can stop and resume bit-identically.
//!
//! Binary format (little-endian):
//! ```text
//! magic "RSCK" | version u32 | step u64 | seed u64
//! | view_epoch u64                                 (version >= 2)
//! | chunk_elems u32                                (version >= 3)
//! | n_layers u32
//! per layer: n u64 | flags u32 | params f32[n]
//!            [residual f32[n] | momentum f32[n]]   (flag bit 0)
//!            [velocity f32[n]]                     (flag bit 1)
//! digest table (version >= 3): per layer, per present section in
//!            params/residual/momentum/velocity order:
//!            n_chunks u32 | chunk digest u64 × n_chunks
//! trailer: fnv hash u64 of everything above
//! ```
//!
//! Version 2 added the membership `view_epoch` (DESIGN.md
//! §Elastic-Membership): resumes and rejoins re-key the data sharder by
//! `(seed, view_epoch, rank)`, so the epoch must travel with the state.
//!
//! Version 3 adds the per-chunk digest table (DESIGN.md
//! §Checkpoint-Repository): every section is chunked at `chunk_elems`
//! f32 values and each chunk carries its streaming FNV-1a digest — the
//! same content address the [`crate::elastic::repo`] store and the
//! delta-rejoin protocol key on, so a checkpoint file *is* a manifest.
//! Version-1 and version-2 blobs still parse (epoch 0 / no table).
//!
//! Writes are atomic: [`write_atomic`] goes temp-file → fsync → rename,
//! so a crash mid-write can never shadow a previously good checkpoint.

use std::io::{Read, Write};
use std::path::Path;

use crate::elastic::chunk;

const MAGIC: &[u8; 4] = b"RSCK";
const VERSION: u32 = 3;

/// Why a checkpoint could not be read, with the offending path and a
/// remedy in the message. `Checkpoint::from_bytes` reports `path` as
/// `<bytes>`; `Checkpoint::load` patches the real path in via [`CheckpointError::at`].
#[derive(Debug)]
pub enum CheckpointError {
    Io(std::io::Error),
    /// No file at the path — nothing was ever saved there.
    Missing { path: String },
    /// File shorter than the fixed header + trailer: a torn or
    /// interrupted write.
    ShortRead { path: String, len: usize },
    /// First four bytes are not "RSCK": not a checkpoint at all.
    BadMagic { path: String },
    /// A version this binary does not understand.
    BadVersion { path: String, version: u32 },
    /// Whole-file FNV trailer mismatch: bit corruption on disk.
    Digest { path: String, stored: u64, computed: u64 },
    /// Structurally inconsistent (truncated tensor, bad digest table, …).
    Corrupt { path: String, detail: String },
}

fn p(path: &str) -> &str {
    if path.is_empty() { "<bytes>" } else { path }
}

impl CheckpointError {
    /// Attach the file path to an error produced while parsing bytes.
    pub fn at(self, path: &str) -> Self {
        let path = path.to_string();
        match self {
            CheckpointError::Io(e) => CheckpointError::Io(e),
            CheckpointError::Missing { .. } => CheckpointError::Missing { path },
            CheckpointError::ShortRead { len, .. } => CheckpointError::ShortRead { path, len },
            CheckpointError::BadMagic { .. } => CheckpointError::BadMagic { path },
            CheckpointError::BadVersion { version, .. } => {
                CheckpointError::BadVersion { path, version }
            }
            CheckpointError::Digest { stored, computed, .. } => {
                CheckpointError::Digest { path, stored, computed }
            }
            CheckpointError::Corrupt { detail, .. } => CheckpointError::Corrupt { path, detail },
        }
    }
}

impl std::fmt::Display for CheckpointError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CheckpointError::Io(e) => write!(f, "io: {e}"),
            CheckpointError::Missing { path } => write!(
                f,
                "checkpoint {} does not exist: nothing was saved under this prefix — \
                 point --resume at a prefix a --ckpt run wrote, or drop --resume to start fresh",
                p(path)
            ),
            CheckpointError::ShortRead { path, len } => write!(
                f,
                "checkpoint {} is only {len} bytes, shorter than a valid header: \
                 a write was torn or interrupted — resume from the previous checkpoint \
                 (atomic saves never overwrite it)",
                p(path)
            ),
            CheckpointError::BadMagic { path } => write!(
                f,
                "{} is not a redsync checkpoint (bad magic): \
                 check that --resume points at an .rsck file written by --ckpt",
                p(path)
            ),
            CheckpointError::BadVersion { path, version } => write!(
                f,
                "checkpoint {} has unsupported version {version}: it was written by a \
                 different redsync build — re-save it with this binary or upgrade",
                p(path)
            ),
            CheckpointError::Digest { path, stored, computed } => write!(
                f,
                "checkpoint {} failed digest verification (stored {stored:#018x}, \
                 computed {computed:#018x}): the file is bit-corrupt on disk — restore \
                 it from the checkpoint repository (--ckpt-repo) or an older snapshot",
                p(path)
            ),
            CheckpointError::Corrupt { path, detail } => {
                write!(f, "checkpoint {} corrupt: {detail}", p(path))
            }
        }
    }
}

impl std::error::Error for CheckpointError {}

impl From<std::io::Error> for CheckpointError {
    fn from(e: std::io::Error) -> Self {
        CheckpointError::Io(e)
    }
}

fn corrupt(detail: impl Into<String>) -> CheckpointError {
    CheckpointError::Corrupt { path: String::new(), detail: detail.into() }
}

/// Write `bytes` to `path` atomically: temp file in the same directory,
/// fsync, then rename over the destination. A crash at any point leaves
/// either the old file or the new one — never a torn mix.
pub fn write_atomic(path: impl AsRef<Path>, bytes: &[u8]) -> std::io::Result<()> {
    let path = path.as_ref();
    let tmp = std::path::PathBuf::from(format!(
        "{}.tmp.{}",
        path.display(),
        std::process::id()
    ));
    let mut f = std::fs::File::create(&tmp)?;
    f.write_all(bytes)?;
    f.sync_all()?;
    drop(f);
    std::fs::rename(&tmp, path)?;
    Ok(())
}

/// One layer's persisted state.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct LayerState {
    pub params: Vec<f32>,
    /// residual V + momentum U (compressed layers).
    pub residual: Option<(Vec<f32>, Vec<f32>)>,
    /// dense-path optimizer velocity.
    pub velocity: Option<Vec<f32>>,
}

impl LayerState {
    /// The present sections in serialization order, with their names
    /// (params / residual / momentum / velocity).
    pub fn sections(&self) -> Vec<(&'static str, &[f32])> {
        let mut out: Vec<(&'static str, &[f32])> = vec![("params", &self.params)];
        if let Some((v, u)) = &self.residual {
            out.push(("residual", v));
            out.push(("momentum", u));
        }
        if let Some(vel) = &self.velocity {
            out.push(("velocity", vel));
        }
        out
    }
}

/// Full training state at a step boundary.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Checkpoint {
    pub step: u64,
    pub seed: u64,
    /// Membership view epoch the state was taken under (0 for a fresh
    /// run; bumped by every elastic reshape/rejoin).
    pub view_epoch: u64,
    pub layers: Vec<LayerState>,
}

fn fnv(h: &mut u64, bytes: &[u8]) {
    for &b in bytes {
        *h ^= b as u64;
        *h = h.wrapping_mul(0x100000001b3);
    }
}

fn put_f32s(out: &mut Vec<u8>, h: &mut u64, xs: &[f32]) {
    let start = out.len();
    out.reserve(xs.len() * 4);
    for &v in xs {
        out.extend_from_slice(&v.to_le_bytes());
    }
    fnv(h, &out[start..]);
}

fn get_f32s(buf: &[u8], pos: &mut usize, n: usize) -> Result<Vec<f32>, CheckpointError> {
    let need = n * 4;
    if buf.len() < *pos + need {
        return Err(corrupt("truncated tensor"));
    }
    let out = buf[*pos..*pos + need]
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    *pos += need;
    Ok(out)
}

impl Checkpoint {
    /// Serialize to bytes (version 3: digest table + trailer hash). The
    /// digest table is computed at [`chunk::DEFAULT_CHUNK_ELEMS`]; it is
    /// derived data, so it does not appear in the in-memory struct.
    pub fn to_bytes(&self) -> Vec<u8> {
        self.to_bytes_chunked(chunk::DEFAULT_CHUNK_ELEMS)
    }

    /// Serialize with an explicit chunk width for the digest table.
    pub fn to_bytes_chunked(&self, chunk_elems: usize) -> Vec<u8> {
        assert!(chunk_elems > 0, "chunk_elems must be positive");
        let mut out = Vec::new();
        let mut h: u64 = 0xcbf29ce484222325;
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&VERSION.to_le_bytes());
        out.extend_from_slice(&self.step.to_le_bytes());
        out.extend_from_slice(&self.seed.to_le_bytes());
        out.extend_from_slice(&self.view_epoch.to_le_bytes());
        out.extend_from_slice(&(chunk_elems as u32).to_le_bytes());
        out.extend_from_slice(&(self.layers.len() as u32).to_le_bytes());
        fnv(&mut h, &out[..]);
        for l in &self.layers {
            let mut head = Vec::with_capacity(12);
            head.extend_from_slice(&(l.params.len() as u64).to_le_bytes());
            let flags: u32 = (l.residual.is_some() as u32) | ((l.velocity.is_some() as u32) << 1);
            head.extend_from_slice(&flags.to_le_bytes());
            fnv(&mut h, &head);
            out.extend_from_slice(&head);
            put_f32s(&mut out, &mut h, &l.params);
            if let Some((v, u)) = &l.residual {
                put_f32s(&mut out, &mut h, v);
                put_f32s(&mut out, &mut h, u);
            }
            if let Some(vel) = &l.velocity {
                put_f32s(&mut out, &mut h, vel);
            }
        }
        for l in &self.layers {
            for (_, xs) in l.sections() {
                let digests = chunk::section_digests(xs, chunk_elems);
                let start = out.len();
                out.extend_from_slice(&(digests.len() as u32).to_le_bytes());
                for d in &digests {
                    out.extend_from_slice(&d.to_le_bytes());
                }
                fnv(&mut h, &out[start..]);
            }
        }
        out.extend_from_slice(&h.to_le_bytes());
        out
    }

    /// Parse from bytes, verifying magic, version, the whole-file hash
    /// and (version 3) every per-chunk digest.
    pub fn from_bytes(buf: &[u8]) -> Result<Checkpoint, CheckpointError> {
        // v1 minimum: magic + version + step + seed + n_layers + trailer.
        if buf.len() < 4 + 4 + 8 + 8 + 4 + 8 {
            return Err(CheckpointError::ShortRead { path: String::new(), len: buf.len() });
        }
        if &buf[..4] != MAGIC {
            return Err(CheckpointError::BadMagic { path: String::new() });
        }
        let body = &buf[..buf.len() - 8];
        let mut h: u64 = 0xcbf29ce484222325;
        fnv(&mut h, body);
        let stored = u64::from_le_bytes(buf[buf.len() - 8..].try_into().unwrap());
        if h != stored {
            return Err(CheckpointError::Digest { path: String::new(), stored, computed: h });
        }
        let mut pos = 4;
        let rd_u32 = |buf: &[u8], pos: &mut usize| {
            let v = u32::from_le_bytes(buf[*pos..*pos + 4].try_into().unwrap());
            *pos += 4;
            v
        };
        let rd_u64 = |buf: &[u8], pos: &mut usize| {
            let v = u64::from_le_bytes(buf[*pos..*pos + 8].try_into().unwrap());
            *pos += 8;
            v
        };
        let version = rd_u32(body, &mut pos);
        if version == 0 || version > VERSION {
            return Err(CheckpointError::BadVersion { path: String::new(), version });
        }
        let step = rd_u64(body, &mut pos);
        let seed = rd_u64(body, &mut pos);
        let view_epoch = if version >= 2 {
            if body.len() < pos + 8 {
                return Err(corrupt("truncated view epoch"));
            }
            rd_u64(body, &mut pos)
        } else {
            0
        };
        let chunk_elems = if version >= 3 {
            if body.len() < pos + 4 {
                return Err(corrupt("truncated chunk width"));
            }
            let c = rd_u32(body, &mut pos) as usize;
            if c == 0 {
                return Err(corrupt("zero chunk width"));
            }
            c
        } else {
            0
        };
        if body.len() < pos + 4 {
            return Err(corrupt("truncated layer count"));
        }
        let n_layers = rd_u32(body, &mut pos) as usize;
        let mut layers = Vec::with_capacity(n_layers);
        for _ in 0..n_layers {
            if body.len() < pos + 12 {
                return Err(corrupt("truncated layer header"));
            }
            let n = rd_u64(body, &mut pos) as usize;
            let flags = rd_u32(body, &mut pos);
            let params = get_f32s(body, &mut pos, n)?;
            let residual = if flags & 1 != 0 {
                Some((get_f32s(body, &mut pos, n)?, get_f32s(body, &mut pos, n)?))
            } else {
                None
            };
            let velocity =
                if flags & 2 != 0 { Some(get_f32s(body, &mut pos, n)?) } else { None };
            layers.push(LayerState { params, residual, velocity });
        }
        if version >= 3 {
            for (li, l) in layers.iter().enumerate() {
                for (name, xs) in l.sections() {
                    if body.len() < pos + 4 {
                        return Err(corrupt("truncated digest table"));
                    }
                    let k = rd_u32(body, &mut pos) as usize;
                    if k != chunk::chunk_count(xs.len(), chunk_elems) {
                        return Err(corrupt(format!(
                            "layer {li} {name}: digest table lists {k} chunks, \
                             section has {}",
                            chunk::chunk_count(xs.len(), chunk_elems)
                        )));
                    }
                    for ci in 0..k {
                        if body.len() < pos + 8 {
                            return Err(corrupt("truncated digest table"));
                        }
                        let want = rd_u64(body, &mut pos);
                        let (s, e) = chunk::chunk_range(xs.len(), chunk_elems, ci);
                        let got = chunk::digest_f32(&xs[s..e]);
                        if got != want {
                            return Err(corrupt(format!(
                                "layer {li} {name} chunk {ci}: digest mismatch \
                                 ({got:#018x} vs stored {want:#018x})"
                            )));
                        }
                    }
                }
            }
        }
        if pos != body.len() {
            return Err(corrupt("trailing bytes"));
        }
        Ok(Checkpoint { step, seed, view_epoch, layers })
    }

    /// Save atomically (temp file → fsync → rename): a crash mid-write
    /// never shadows a previously good checkpoint at `path`.
    pub fn save(&self, path: impl AsRef<Path>) -> Result<(), CheckpointError> {
        write_atomic(path, &self.to_bytes())?;
        Ok(())
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Checkpoint, CheckpointError> {
        let path = path.as_ref();
        let shown = path.display().to_string();
        let mut buf = Vec::new();
        let mut f = match std::fs::File::open(path) {
            Ok(f) => f,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                return Err(CheckpointError::Missing { path: shown });
            }
            Err(e) => return Err(CheckpointError::Io(e)),
        };
        f.read_to_end(&mut buf)?;
        Checkpoint::from_bytes(&buf).map_err(|e| e.at(&shown))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn sample() -> Checkpoint {
        let mut rng = Pcg32::seeded(3);
        let mut mk = |n: usize| {
            let mut v = vec![0f32; n];
            rng.fill_normal(&mut v, 1.0);
            v
        };
        Checkpoint {
            step: 1234,
            seed: 42,
            view_epoch: 3,
            layers: vec![
                LayerState {
                    params: mk(100),
                    residual: Some((mk(100), mk(100))),
                    velocity: None,
                },
                LayerState { params: mk(7), residual: None, velocity: Some(mk(7)) },
                LayerState { params: mk(1), residual: None, velocity: None },
            ],
        }
    }

    #[test]
    fn roundtrip_bytes() {
        let ck = sample();
        let bytes = ck.to_bytes();
        let back = Checkpoint::from_bytes(&bytes).unwrap();
        assert_eq!(ck, back);
    }

    #[test]
    fn roundtrip_bytes_odd_chunk_width() {
        let ck = sample();
        // chunk width that never divides the section sizes evenly
        let back = Checkpoint::from_bytes(&ck.to_bytes_chunked(33)).unwrap();
        assert_eq!(ck, back);
    }

    #[test]
    fn roundtrip_file() {
        let ck = sample();
        let path = std::env::temp_dir().join(format!("rsck_{}", std::process::id()));
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(ck, back);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn corruption_detected() {
        let ck = sample();
        let mut bytes = ck.to_bytes();
        // flip a payload bit: the whole-file trailer catches it first
        let mid = bytes.len() / 2;
        bytes[mid] ^= 1;
        assert!(matches!(
            Checkpoint::from_bytes(&bytes),
            Err(CheckpointError::Digest { .. })
        ));
    }

    #[test]
    fn bad_magic_and_version() {
        let ck = sample();
        let mut bytes = ck.to_bytes();
        bytes[0] = b'X';
        assert!(matches!(
            Checkpoint::from_bytes(&bytes),
            Err(CheckpointError::BadMagic { .. })
        ));
        let mut bytes = ck.to_bytes();
        bytes[4] = 99;
        // version is inside the hash: corrupt hash fires first — either
        // error is acceptable, but it must not parse
        assert!(Checkpoint::from_bytes(&bytes).is_err());
    }

    #[test]
    fn truncation_detected() {
        let bytes = sample().to_bytes();
        for cut in [3usize, 10, bytes.len() / 2, bytes.len() - 1] {
            assert!(Checkpoint::from_bytes(&bytes[..cut]).is_err(), "cut {cut}");
        }
    }

    #[test]
    fn empty_checkpoint_roundtrips() {
        let ck = Checkpoint { step: 0, seed: 0, view_epoch: 0, layers: vec![] };
        assert_eq!(Checkpoint::from_bytes(&ck.to_bytes()).unwrap(), ck);
    }

    #[test]
    fn resume_failures_are_distinct_and_name_the_path() {
        let dir = std::env::temp_dir().join(format!("rsck_err_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();

        // missing file
        let missing = dir.join("never_written.rsck");
        let e = Checkpoint::load(&missing).unwrap_err();
        assert!(matches!(e, CheckpointError::Missing { .. }), "{e}");
        assert!(e.to_string().contains("never_written.rsck"), "{e}");
        assert!(e.to_string().contains("--resume"), "remedy missing: {e}");

        // short read (torn write)
        let short = dir.join("short.rsck");
        std::fs::write(&short, b"RSCK\x03").unwrap();
        let e = Checkpoint::load(&short).unwrap_err();
        assert!(matches!(e, CheckpointError::ShortRead { len: 5, .. }), "{e}");
        assert!(e.to_string().contains("short.rsck"), "{e}");

        // bad magic
        let junk = dir.join("junk.rsck");
        std::fs::write(&junk, vec![0u8; 64]).unwrap();
        let e = Checkpoint::load(&junk).unwrap_err();
        assert!(matches!(e, CheckpointError::BadMagic { .. }), "{e}");
        assert!(e.to_string().contains("junk.rsck"), "{e}");

        // digest mismatch
        let corrupt = dir.join("corrupt.rsck");
        let mut bytes = sample().to_bytes();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x10;
        std::fs::write(&corrupt, &bytes).unwrap();
        let e = Checkpoint::load(&corrupt).unwrap_err();
        assert!(matches!(e, CheckpointError::Digest { .. }), "{e}");
        assert!(e.to_string().contains("corrupt.rsck"), "{e}");
        assert!(e.to_string().contains("--ckpt-repo"), "remedy missing: {e}");

        // future version
        let future = dir.join("future.rsck");
        let mut bytes = sample().to_bytes();
        bytes[4..8].copy_from_slice(&99u32.to_le_bytes());
        // re-seal the trailer so only the version check can fire
        let mut h: u64 = 0xcbf29ce484222325;
        let end = bytes.len() - 8;
        fnv(&mut h, &bytes[..end]);
        bytes[end..].copy_from_slice(&h.to_le_bytes());
        std::fs::write(&future, &bytes).unwrap();
        let e = Checkpoint::load(&future).unwrap_err();
        assert!(matches!(e, CheckpointError::BadVersion { version: 99, .. }), "{e}");
        assert!(e.to_string().contains("future.rsck"), "{e}");

        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn torn_write_never_shadows_prior_checkpoint() {
        // Atomic saves write to `{path}.tmp.{pid}` then rename. Simulate
        // a crash at every byte boundary of the temp write and assert the
        // previously saved checkpoint still loads bit-identical.
        let dir = std::env::temp_dir().join(format!("rsck_torn_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("state.rsck");

        let prior = Checkpoint {
            step: 6,
            seed: 9,
            view_epoch: 1,
            layers: vec![LayerState {
                params: vec![1.0, -2.0, 3.5],
                residual: Some((vec![0.1, 0.2, 0.3], vec![0.0; 3])),
                velocity: None,
            }],
        };
        prior.save(&path).unwrap();

        let mut next = prior.clone();
        next.step = 12;
        next.layers[0].params[0] = 7.25;
        let next_bytes = next.to_bytes();
        let tmp = format!("{}.tmp.{}", path.display(), std::process::id());

        for cut in 0..=next_bytes.len() {
            std::fs::write(&tmp, &next_bytes[..cut]).unwrap();
            // crash here: the rename never happened
            let loaded = Checkpoint::load(&path).unwrap();
            assert_eq!(loaded, prior, "torn write at byte {cut} shadowed the prior checkpoint");
            // and the torn temp itself must never parse as valid unless complete
            if cut < next_bytes.len() {
                assert!(Checkpoint::from_bytes(&next_bytes[..cut]).is_err(), "cut {cut}");
            }
        }
        // a completed write (rename) does replace it
        std::fs::rename(&tmp, &path).unwrap();
        assert_eq!(Checkpoint::load(&path).unwrap(), next);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn version_1_blobs_still_parse_with_epoch_zero() {
        // hand-build a v1 blob: same layout minus the view_epoch field
        let ck = sample();
        let mut out = Vec::new();
        let mut h: u64 = 0xcbf29ce484222325;
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&1u32.to_le_bytes());
        out.extend_from_slice(&ck.step.to_le_bytes());
        out.extend_from_slice(&ck.seed.to_le_bytes());
        out.extend_from_slice(&(ck.layers.len() as u32).to_le_bytes());
        fnv(&mut h, &out[..]);
        for l in &ck.layers {
            let mut head = Vec::with_capacity(12);
            head.extend_from_slice(&(l.params.len() as u64).to_le_bytes());
            let flags: u32 = (l.residual.is_some() as u32) | ((l.velocity.is_some() as u32) << 1);
            head.extend_from_slice(&flags.to_le_bytes());
            fnv(&mut h, &head);
            out.extend_from_slice(&head);
            put_f32s(&mut out, &mut h, &l.params);
            if let Some((v, u)) = &l.residual {
                put_f32s(&mut out, &mut h, v);
                put_f32s(&mut out, &mut h, u);
            }
            if let Some(vel) = &l.velocity {
                put_f32s(&mut out, &mut h, vel);
            }
        }
        out.extend_from_slice(&h.to_le_bytes());
        let back = Checkpoint::from_bytes(&out).unwrap();
        assert_eq!(back.view_epoch, 0, "v1 blobs predate membership epochs");
        assert_eq!(back.layers, ck.layers);
        assert_eq!((back.step, back.seed), (ck.step, ck.seed));
    }

    #[test]
    fn version_2_blobs_still_parse_without_digest_table() {
        // hand-build a v2 blob: view_epoch present, no chunk width or
        // digest table
        let ck = sample();
        let mut out = Vec::new();
        let mut h: u64 = 0xcbf29ce484222325;
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&2u32.to_le_bytes());
        out.extend_from_slice(&ck.step.to_le_bytes());
        out.extend_from_slice(&ck.seed.to_le_bytes());
        out.extend_from_slice(&ck.view_epoch.to_le_bytes());
        out.extend_from_slice(&(ck.layers.len() as u32).to_le_bytes());
        fnv(&mut h, &out[..]);
        for l in &ck.layers {
            let mut head = Vec::with_capacity(12);
            head.extend_from_slice(&(l.params.len() as u64).to_le_bytes());
            let flags: u32 = (l.residual.is_some() as u32) | ((l.velocity.is_some() as u32) << 1);
            head.extend_from_slice(&flags.to_le_bytes());
            fnv(&mut h, &head);
            out.extend_from_slice(&head);
            put_f32s(&mut out, &mut h, &l.params);
            if let Some((v, u)) = &l.residual {
                put_f32s(&mut out, &mut h, v);
                put_f32s(&mut out, &mut h, u);
            }
            if let Some(vel) = &l.velocity {
                put_f32s(&mut out, &mut h, vel);
            }
        }
        out.extend_from_slice(&h.to_le_bytes());
        let back = Checkpoint::from_bytes(&out).unwrap();
        assert_eq!(back, ck, "v2 blob must parse to the identical checkpoint");
    }
}
