//! Checkpointing: serialize the full training state — parameters,
//! per-layer residuals and momentum buffers, optimizer velocity and the
//! step counter — so a run can stop and resume bit-identically.
//!
//! Binary format (little-endian):
//! ```text
//! magic "RSCK" | version u32 | step u64 | seed u64
//! | view_epoch u64                                 (version >= 2)
//! | n_layers u32
//! per layer: n u64 | params f32[n] | flags u32
//!            [residual f32[n] | momentum f32[n]]   (flag bit 0)
//!            [velocity f32[n]]                     (flag bit 1)
//! trailer: fnv hash u64 of everything above
//! ```
//!
//! Version 2 adds the membership `view_epoch` (DESIGN.md
//! §Elastic-Membership): resumes and rejoins re-key the data sharder by
//! `(seed, view_epoch, rank)`, so the epoch must travel with the state.
//! Version-1 blobs still parse (epoch 0).

use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 4] = b"RSCK";
const VERSION: u32 = 2;

#[derive(Debug)]
pub enum CheckpointError {
    Io(std::io::Error),
    BadMagic,
    BadVersion(u32),
    Corrupt(String),
}

impl std::fmt::Display for CheckpointError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CheckpointError::Io(e) => write!(f, "io: {e}"),
            CheckpointError::BadMagic => write!(f, "not a redsync checkpoint (bad magic)"),
            CheckpointError::BadVersion(v) => write!(f, "unsupported checkpoint version {v}"),
            CheckpointError::Corrupt(msg) => write!(f, "checkpoint corrupt: {msg}"),
        }
    }
}

impl std::error::Error for CheckpointError {}

impl From<std::io::Error> for CheckpointError {
    fn from(e: std::io::Error) -> Self {
        CheckpointError::Io(e)
    }
}

/// One layer's persisted state.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct LayerState {
    pub params: Vec<f32>,
    /// residual V + momentum U (compressed layers).
    pub residual: Option<(Vec<f32>, Vec<f32>)>,
    /// dense-path optimizer velocity.
    pub velocity: Option<Vec<f32>>,
}

/// Full training state at a step boundary.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Checkpoint {
    pub step: u64,
    pub seed: u64,
    /// Membership view epoch the state was taken under (0 for a fresh
    /// run; bumped by every elastic reshape/rejoin).
    pub view_epoch: u64,
    pub layers: Vec<LayerState>,
}

fn fnv(h: &mut u64, bytes: &[u8]) {
    for &b in bytes {
        *h ^= b as u64;
        *h = h.wrapping_mul(0x100000001b3);
    }
}

fn put_f32s(out: &mut Vec<u8>, h: &mut u64, xs: &[f32]) {
    let start = out.len();
    out.reserve(xs.len() * 4);
    for &v in xs {
        out.extend_from_slice(&v.to_le_bytes());
    }
    fnv(h, &out[start..]);
}

fn get_f32s(buf: &[u8], pos: &mut usize, n: usize) -> Result<Vec<f32>, CheckpointError> {
    let need = n * 4;
    if buf.len() < *pos + need {
        return Err(CheckpointError::Corrupt("truncated tensor".into()));
    }
    let out = buf[*pos..*pos + need]
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    *pos += need;
    Ok(out)
}

impl Checkpoint {
    /// Serialize to bytes (with trailer hash).
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::new();
        let mut h: u64 = 0xcbf29ce484222325;
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&VERSION.to_le_bytes());
        out.extend_from_slice(&self.step.to_le_bytes());
        out.extend_from_slice(&self.seed.to_le_bytes());
        out.extend_from_slice(&self.view_epoch.to_le_bytes());
        out.extend_from_slice(&(self.layers.len() as u32).to_le_bytes());
        fnv(&mut h, &out[..]);
        for l in &self.layers {
            let mut head = Vec::with_capacity(12);
            head.extend_from_slice(&(l.params.len() as u64).to_le_bytes());
            let flags: u32 = (l.residual.is_some() as u32) | ((l.velocity.is_some() as u32) << 1);
            head.extend_from_slice(&flags.to_le_bytes());
            fnv(&mut h, &head);
            out.extend_from_slice(&head);
            put_f32s(&mut out, &mut h, &l.params);
            if let Some((v, u)) = &l.residual {
                put_f32s(&mut out, &mut h, v);
                put_f32s(&mut out, &mut h, u);
            }
            if let Some(vel) = &l.velocity {
                put_f32s(&mut out, &mut h, vel);
            }
        }
        out.extend_from_slice(&h.to_le_bytes());
        out
    }

    /// Parse from bytes, verifying magic/version/hash.
    pub fn from_bytes(buf: &[u8]) -> Result<Checkpoint, CheckpointError> {
        if buf.len() < 4 + 4 + 8 + 8 + 4 + 8 {
            return Err(CheckpointError::Corrupt("too short".into()));
        }
        if &buf[..4] != MAGIC {
            return Err(CheckpointError::BadMagic);
        }
        let body = &buf[..buf.len() - 8];
        let mut h: u64 = 0xcbf29ce484222325;
        fnv(&mut h, body);
        let stored = u64::from_le_bytes(buf[buf.len() - 8..].try_into().unwrap());
        if h != stored {
            return Err(CheckpointError::Corrupt(format!(
                "hash mismatch: {h:#x} vs {stored:#x}"
            )));
        }
        let mut pos = 4;
        let rd_u32 = |buf: &[u8], pos: &mut usize| {
            let v = u32::from_le_bytes(buf[*pos..*pos + 4].try_into().unwrap());
            *pos += 4;
            v
        };
        let rd_u64 = |buf: &[u8], pos: &mut usize| {
            let v = u64::from_le_bytes(buf[*pos..*pos + 8].try_into().unwrap());
            *pos += 8;
            v
        };
        let version = rd_u32(body, &mut pos);
        if version == 0 || version > VERSION {
            return Err(CheckpointError::BadVersion(version));
        }
        let step = rd_u64(body, &mut pos);
        let seed = rd_u64(body, &mut pos);
        let view_epoch = if version >= 2 {
            if body.len() < pos + 8 {
                return Err(CheckpointError::Corrupt("truncated view epoch".into()));
            }
            rd_u64(body, &mut pos)
        } else {
            0
        };
        if body.len() < pos + 4 {
            return Err(CheckpointError::Corrupt("truncated layer count".into()));
        }
        let n_layers = rd_u32(body, &mut pos) as usize;
        let mut layers = Vec::with_capacity(n_layers);
        for _ in 0..n_layers {
            if body.len() < pos + 12 {
                return Err(CheckpointError::Corrupt("truncated layer header".into()));
            }
            let n = rd_u64(body, &mut pos) as usize;
            let flags = rd_u32(body, &mut pos);
            let params = get_f32s(body, &mut pos, n)?;
            let residual = if flags & 1 != 0 {
                Some((get_f32s(body, &mut pos, n)?, get_f32s(body, &mut pos, n)?))
            } else {
                None
            };
            let velocity =
                if flags & 2 != 0 { Some(get_f32s(body, &mut pos, n)?) } else { None };
            layers.push(LayerState { params, residual, velocity });
        }
        if pos != body.len() {
            return Err(CheckpointError::Corrupt("trailing bytes".into()));
        }
        Ok(Checkpoint { step, seed, view_epoch, layers })
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<(), CheckpointError> {
        let mut f = std::fs::File::create(path)?;
        f.write_all(&self.to_bytes())?;
        Ok(())
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Checkpoint, CheckpointError> {
        let mut buf = Vec::new();
        std::fs::File::open(path)?.read_to_end(&mut buf)?;
        Checkpoint::from_bytes(&buf)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn sample() -> Checkpoint {
        let mut rng = Pcg32::seeded(3);
        let mut mk = |n: usize| {
            let mut v = vec![0f32; n];
            rng.fill_normal(&mut v, 1.0);
            v
        };
        Checkpoint {
            step: 1234,
            seed: 42,
            view_epoch: 3,
            layers: vec![
                LayerState {
                    params: mk(100),
                    residual: Some((mk(100), mk(100))),
                    velocity: None,
                },
                LayerState { params: mk(7), residual: None, velocity: Some(mk(7)) },
                LayerState { params: mk(1), residual: None, velocity: None },
            ],
        }
    }

    #[test]
    fn roundtrip_bytes() {
        let ck = sample();
        let bytes = ck.to_bytes();
        let back = Checkpoint::from_bytes(&bytes).unwrap();
        assert_eq!(ck, back);
    }

    #[test]
    fn roundtrip_file() {
        let ck = sample();
        let path = std::env::temp_dir().join(format!("rsck_{}", std::process::id()));
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(ck, back);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn corruption_detected() {
        let ck = sample();
        let mut bytes = ck.to_bytes();
        // flip a payload bit
        let mid = bytes.len() / 2;
        bytes[mid] ^= 1;
        assert!(matches!(
            Checkpoint::from_bytes(&bytes),
            Err(CheckpointError::Corrupt(_))
        ));
    }

    #[test]
    fn bad_magic_and_version() {
        let ck = sample();
        let mut bytes = ck.to_bytes();
        bytes[0] = b'X';
        assert!(matches!(Checkpoint::from_bytes(&bytes), Err(CheckpointError::BadMagic)));
        let mut bytes = ck.to_bytes();
        bytes[4] = 99;
        // version is inside the hash: corrupt hash fires first — either
        // error is acceptable, but it must not parse
        assert!(Checkpoint::from_bytes(&bytes).is_err());
    }

    #[test]
    fn truncation_detected() {
        let bytes = sample().to_bytes();
        for cut in [3usize, 10, bytes.len() / 2, bytes.len() - 1] {
            assert!(Checkpoint::from_bytes(&bytes[..cut]).is_err(), "cut {cut}");
        }
    }

    #[test]
    fn empty_checkpoint_roundtrips() {
        let ck = Checkpoint { step: 0, seed: 0, view_epoch: 0, layers: vec![] };
        assert_eq!(Checkpoint::from_bytes(&ck.to_bytes()).unwrap(), ck);
    }

    #[test]
    fn version_1_blobs_still_parse_with_epoch_zero() {
        // hand-build a v1 blob: same layout minus the view_epoch field
        let ck = sample();
        let mut out = Vec::new();
        let mut h: u64 = 0xcbf29ce484222325;
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&1u32.to_le_bytes());
        out.extend_from_slice(&ck.step.to_le_bytes());
        out.extend_from_slice(&ck.seed.to_le_bytes());
        out.extend_from_slice(&(ck.layers.len() as u32).to_le_bytes());
        fnv(&mut h, &out[..]);
        for l in &ck.layers {
            let mut head = Vec::with_capacity(12);
            head.extend_from_slice(&(l.params.len() as u64).to_le_bytes());
            let flags: u32 = (l.residual.is_some() as u32) | ((l.velocity.is_some() as u32) << 1);
            head.extend_from_slice(&flags.to_le_bytes());
            fnv(&mut h, &head);
            out.extend_from_slice(&head);
            put_f32s(&mut out, &mut h, &l.params);
            if let Some((v, u)) = &l.residual {
                put_f32s(&mut out, &mut h, v);
                put_f32s(&mut out, &mut h, u);
            }
            if let Some(vel) = &l.velocity {
                put_f32s(&mut out, &mut h, vel);
            }
        }
        out.extend_from_slice(&h.to_le_bytes());
        let back = Checkpoint::from_bytes(&out).unwrap();
        assert_eq!(back.view_epoch, 0, "v1 blobs predate membership epochs");
        assert_eq!(back.layers, ck.layers);
        assert_eq!((back.step, back.seed), (ck.step, ck.seed));
    }
}
