//! Synthetic datasets (DESIGN.md §Substitutions: stand-ins for Cifar10 /
//! ImageNet / PTB / Wiki2 on a no-dataset testbed).
//!
//! * [`ZipfMarkovCorpus`] — token streams with Zipfian unigram mass and
//!   first-order Markov structure: enough signal for a language model to
//!   reduce loss well below the unigram entropy, deterministic per seed.
//! * [`ClusterDataset`] — Gaussian-cluster classification with controllable
//!   margin: the proxy task for the accuracy experiments (Fig. 6,
//!   Tables 1-2).
//!
//! Each worker shards the stream by `(seed, rank)` so data parallelism
//! sees disjoint data, mirroring the paper's per-node dataset shards.

use crate::util::rng::Pcg32;

/// Zipf-Markov synthetic LM corpus.
pub struct ZipfMarkovCorpus {
    vocab: usize,
    /// per-state cumulative transition distributions (`states x vocab`)
    cdfs: Vec<Vec<f32>>,
    n_states: usize,
}

impl ZipfMarkovCorpus {
    /// Build a corpus model with `n_states` Markov states over `vocab`
    /// tokens, Zipf exponent `s` (≈1.0 natural).
    pub fn new(vocab: usize, seed: u64, s: f64) -> Self {
        assert!(vocab >= 4);
        let n_states = 16.min(vocab);
        let mut rng = Pcg32::new(seed, 0x2157);
        let mut cdfs = Vec::with_capacity(n_states);
        for _ in 0..n_states {
            // Zipf base mass with a random permutation + multiplicative
            // noise per state -> distinct transition rows
            let mut weights: Vec<f32> = (0..vocab)
                .map(|i| (1.0 / ((i + 1) as f64).powf(s)) as f32)
                .collect();
            rng.shuffle(&mut weights);
            for w in weights.iter_mut() {
                *w *= 0.5 + rng.next_f32();
            }
            let mut cdf = Vec::with_capacity(vocab);
            let mut acc = 0.0f32;
            for w in &weights {
                acc += w;
                cdf.push(acc);
            }
            cdfs.push(cdf);
        }
        ZipfMarkovCorpus { vocab, cdfs, n_states }
    }

    pub fn vocab(&self) -> usize {
        self.vocab
    }

    /// Sample a `(tokens, targets)` LM batch for `rank`: targets are the
    /// next tokens.  Deterministic in (seed-of-self, rank, step).
    pub fn batch(
        &self,
        rank: usize,
        step: usize,
        batch: usize,
        seq: usize,
    ) -> (Vec<i32>, Vec<i32>) {
        self.batch_salted(rank, step, 0, batch, seq)
    }

    /// [`batch`](Self::batch) with a shard salt: the elastic layer
    /// passes the membership view epoch, re-keying every rank's stream
    /// by `(seed, view_epoch, rank)` after a reshape or rejoin so the
    /// new world's shards stay disjoint without replaying old draws.
    /// Salt 0 reproduces `batch` exactly.
    pub fn batch_salted(
        &self,
        rank: usize,
        step: usize,
        salt: u64,
        batch: usize,
        seq: usize,
    ) -> (Vec<i32>, Vec<i32>) {
        let stream = 0xBA7C ^ salt.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut rng = Pcg32::new((step as u64) << 16 | rank as u64, stream);
        let mut tokens = Vec::with_capacity(batch * seq);
        let mut targets = Vec::with_capacity(batch * seq);
        for _ in 0..batch {
            let mut tok = rng.below(self.vocab as u32) as usize;
            for _ in 0..seq {
                let state = tok % self.n_states;
                let next = rng.categorical(&self.cdfs[state]);
                tokens.push(tok as i32);
                targets.push(next as i32);
                tok = next;
            }
        }
        (tokens, targets)
    }
}

/// Gaussian-cluster classification dataset (fixed finite set, so train
/// accuracy is measurable and overfitting observable).
pub struct ClusterDataset {
    pub dim: usize,
    pub classes: usize,
    xs: Vec<f32>,
    ys: Vec<i32>,
    n: usize,
}

impl ClusterDataset {
    /// `margin` scales cluster-center separation relative to the noise
    /// std (1.0): ≈3 is comfortably separable, ≈1 is hard.
    pub fn new(n: usize, dim: usize, classes: usize, margin: f32, seed: u64) -> Self {
        let mut rng = Pcg32::new(seed, 0xC1A5);
        let mut centers = vec![0f32; classes * dim];
        rng.fill_normal(&mut centers, margin);
        let mut xs = vec![0f32; n * dim];
        let mut ys = Vec::with_capacity(n);
        for i in 0..n {
            let c = rng.below(classes as u32) as usize;
            ys.push(c as i32);
            for d in 0..dim {
                xs[i * dim + d] = centers[c * dim + d] + rng.normal();
            }
        }
        ClusterDataset { dim, classes, xs, ys, n }
    }

    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Size of the training split (the leading 80%); the tail 20% is the
    /// held-out split returned by [`Self::eval_split`].
    pub fn train_len(&self) -> usize {
        (self.n * 4 / 5).max(1)
    }

    /// Deterministic batch for `(rank, step)`: samples with replacement
    /// from this worker's shard (disjoint contiguous shards per rank) of
    /// the *training* split.
    pub fn batch(
        &self,
        rank: usize,
        world: usize,
        step: usize,
        batch: usize,
    ) -> (Vec<f32>, Vec<i32>) {
        self.batch_salted(rank, world, step, 0, batch)
    }

    /// [`batch`](Self::batch) re-keyed by a shard salt (the elastic
    /// membership view epoch): shards stay disjoint per rank within a
    /// view, and a reshaped world draws a fresh stream.  Salt 0
    /// reproduces `batch` exactly.
    pub fn batch_salted(
        &self,
        rank: usize,
        world: usize,
        step: usize,
        salt: u64,
        batch: usize,
    ) -> (Vec<f32>, Vec<i32>) {
        let n = self.train_len();
        let shard = n / world;
        let lo = rank * shard;
        let hi = if rank == world - 1 { n } else { lo + shard };
        let stream = (0xBA7C + 1) ^ salt.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut rng = Pcg32::new((step as u64) << 16 | rank as u64, stream);
        let mut xs = Vec::with_capacity(batch * self.dim);
        let mut ys = Vec::with_capacity(batch);
        for _ in 0..batch {
            let i = lo + rng.below((hi - lo) as u32) as usize;
            xs.extend_from_slice(&self.xs[i * self.dim..(i + 1) * self.dim]);
            ys.push(self.ys[i]);
        }
        (xs, ys)
    }

    /// The full dataset.
    pub fn all(&self) -> (&[f32], &[i32]) {
        (&self.xs, &self.ys)
    }

    /// The held-out split (tail 20%) — never sampled by [`Self::batch`].
    pub fn eval_split(&self) -> (&[f32], &[i32]) {
        let lo = self.train_len();
        (&self.xs[lo * self.dim..], &self.ys[lo..])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corpus_batches_deterministic() {
        let c = ZipfMarkovCorpus::new(64, 7, 1.0);
        let (t1, g1) = c.batch(0, 3, 4, 16);
        let (t2, g2) = c.batch(0, 3, 4, 16);
        assert_eq!(t1, t2);
        assert_eq!(g1, g2);
        assert_eq!(t1.len(), 64);
    }

    #[test]
    fn corpus_ranks_differ() {
        let c = ZipfMarkovCorpus::new(64, 7, 1.0);
        assert_ne!(c.batch(0, 0, 4, 16).0, c.batch(1, 0, 4, 16).0);
    }

    #[test]
    fn corpus_tokens_in_vocab() {
        let c = ZipfMarkovCorpus::new(32, 1, 1.0);
        let (t, g) = c.batch(0, 0, 8, 32);
        assert!(t.iter().chain(&g).all(|&x| (0..32).contains(&x)));
    }

    #[test]
    fn corpus_is_predictable_markov() {
        // Given the state, the top transition should be much more likely
        // than uniform: measure empirical max-transition frequency
        let c = ZipfMarkovCorpus::new(64, 3, 1.0);
        let (t, g) = c.batch(0, 0, 64, 64);
        // count most-common target per source state
        let mut counts = std::collections::HashMap::new();
        for (a, b) in t.iter().zip(&g) {
            *counts.entry((a % 16, *b)).or_insert(0usize) += 1;
        }
        let best = counts.values().max().copied().unwrap_or(0);
        assert!(best > t.len() / 64, "markov structure too weak");
    }

    #[test]
    fn clusters_shapes_and_labels() {
        let d = ClusterDataset::new(1000, 16, 4, 3.0, 5);
        assert_eq!(d.len(), 1000);
        let (xs, ys) = d.batch(0, 4, 0, 32);
        assert_eq!(xs.len(), 32 * 16);
        assert!(ys.iter().all(|&y| (0..4).contains(&y)));
    }

    #[test]
    fn salted_batches_rekey_the_stream() {
        // salt 0 is the unsalted stream; a nonzero view epoch draws a
        // different — but still deterministic — batch per (rank, step)
        let c = ZipfMarkovCorpus::new(64, 7, 1.0);
        assert_eq!(c.batch_salted(0, 3, 0, 4, 16), c.batch(0, 3, 4, 16));
        assert_ne!(c.batch_salted(0, 3, 1, 4, 16).0, c.batch(0, 3, 4, 16).0);
        assert_eq!(c.batch_salted(1, 3, 2, 4, 16), c.batch_salted(1, 3, 2, 4, 16));
        let d = ClusterDataset::new(200, 4, 2, 3.0, 5);
        assert_eq!(d.batch_salted(0, 2, 0, 0, 16), d.batch(0, 2, 0, 16));
        assert_ne!(d.batch_salted(0, 2, 0, 1, 16).0, d.batch(0, 2, 0, 16).0);
    }

    #[test]
    fn cluster_shards_disjoint_sources() {
        let d = ClusterDataset::new(100, 4, 2, 3.0, 5);
        // ranks draw from different shards: batches differ
        let (x0, _) = d.batch(0, 4, 0, 16);
        let (x3, _) = d.batch(3, 4, 0, 16);
        assert_ne!(x0, x3);
    }

    #[test]
    fn clusters_separable_at_high_margin() {
        // nearest-center classification should get most right at margin 4
        let classes = 4;
        let dim = 8;
        let d = ClusterDataset::new(400, dim, classes, 4.0, 9);
        let (xs, ys) = d.all();
        // recover centers by class means
        let mut centers = vec![0f32; classes * dim];
        let mut n = vec![0f32; classes];
        for i in 0..d.len() {
            let c = ys[i] as usize;
            n[c] += 1.0;
            for k in 0..dim {
                centers[c * dim + k] += xs[i * dim + k];
            }
        }
        for c in 0..classes {
            for k in 0..dim {
                centers[c * dim + k] /= n[c].max(1.0);
            }
        }
        let mut correct = 0;
        for i in 0..d.len() {
            let mut best = (f32::INFINITY, 0usize);
            for c in 0..classes {
                let dist: f32 = (0..dim)
                    .map(|k| {
                        let diff = xs[i * dim + k] - centers[c * dim + k];
                        diff * diff
                    })
                    .sum();
                if dist < best.0 {
                    best = (dist, c);
                }
            }
            if best.1 == ys[i] as usize {
                correct += 1;
            }
        }
        assert!(correct as f64 / d.len() as f64 > 0.9);
    }
}
