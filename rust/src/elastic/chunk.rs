//! Fixed-size chunking and streaming digests for the checkpoint repository.
//!
//! A checkpoint section (a layer's params, residual, momentum, or velocity
//! vector) is split into fixed-size chunks of `chunk_elems` f32 values; the
//! final chunk may be shorter. Each chunk is identified by a streaming
//! 64-bit FNV-1a digest over its little-endian byte image — the same hash
//! family the RSCK trailer and `param_hash` use, so a digest mismatch means
//! bit-level divergence, not float fuzz.
//!
//! The digest doubles as the content address in [`crate::elastic::repo`]:
//! two chunks with equal digests are stored once and refcounted.

/// Default number of f32 elements per chunk.
///
/// Small enough that a layer of a few thousand parameters splits into
/// several chunks (so partial overlap is expressible), large enough that
/// per-chunk framing overhead stays negligible on the ctrl channel.
pub const DEFAULT_CHUNK_ELEMS: usize = 256;

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x1000_0000_01b3;

/// Streaming FNV-1a 64-bit digest.
///
/// Feed bytes incrementally with [`Digest::update`]; [`Digest::finish`]
/// returns the running hash. Equivalent to hashing the concatenation of
/// all fed slices in one call.
#[derive(Clone, Copy, Debug)]
pub struct Digest {
    h: u64,
}

impl Digest {
    pub fn new() -> Self {
        Digest { h: FNV_OFFSET }
    }

    pub fn update(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.h ^= b as u64;
            self.h = self.h.wrapping_mul(FNV_PRIME);
        }
    }

    pub fn update_f32s(&mut self, xs: &[f32]) {
        for x in xs {
            self.update(&x.to_le_bytes());
        }
    }

    pub fn finish(&self) -> u64 {
        self.h
    }
}

impl Default for Digest {
    fn default() -> Self {
        Self::new()
    }
}

/// Digest of one chunk of f32 values (little-endian byte image).
pub fn digest_f32(xs: &[f32]) -> u64 {
    let mut d = Digest::new();
    d.update_f32s(xs);
    d.finish()
}

/// Number of chunks a section of `n` elements splits into at `chunk_elems`
/// per chunk. Zero-length sections have zero chunks.
pub fn chunk_count(n: usize, chunk_elems: usize) -> usize {
    assert!(chunk_elems > 0, "chunk_elems must be positive");
    n.div_ceil(chunk_elems)
}

/// Byte range `[start, end)` of chunk `idx` within a section of `n`
/// elements (element indices, not bytes).
pub fn chunk_range(n: usize, chunk_elems: usize, idx: usize) -> (usize, usize) {
    let start = idx * chunk_elems;
    assert!(start < n || (n == 0 && idx == 0), "chunk index {idx} out of range for {n} elems");
    (start, (start + chunk_elems).min(n))
}

/// Ordered digests of every chunk of `xs`.
pub fn section_digests(xs: &[f32], chunk_elems: usize) -> Vec<u64> {
    let n = xs.len();
    (0..chunk_count(n, chunk_elems))
        .map(|i| {
            let (s, e) = chunk_range(n, chunk_elems, i);
            digest_f32(&xs[s..e])
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Tiny deterministic generator so tests don't depend on the crate's
    /// RNG plumbing.
    fn gen_f32s(seed: u64, n: usize) -> Vec<f32> {
        let mut s = seed.wrapping_mul(0x9e37_79b9_7f4a_7c15).max(1);
        (0..n)
            .map(|_| {
                s ^= s << 13;
                s ^= s >> 7;
                s ^= s << 17;
                ((s >> 40) as f32) / 1e6 - 8.0
            })
            .collect()
    }

    #[test]
    fn empty_section_has_no_chunks() {
        assert_eq!(chunk_count(0, 64), 0);
        assert!(section_digests(&[], 64).is_empty());
    }

    #[test]
    fn streaming_digest_matches_one_shot() {
        let xs = gen_f32s(3, 1000);
        let one = digest_f32(&xs);
        let mut d = Digest::new();
        for piece in xs.chunks(7) {
            d.update_f32s(piece);
        }
        assert_eq!(one, d.finish(), "streaming digest must equal one-shot digest");
    }

    #[test]
    fn chunk_counts_and_ranges_cover_exactly() {
        // Property-style sweep: empty, aligned, off-by-one, and odd sizes
        // at several chunk widths — ranges must tile [0, n) exactly.
        for &n in &[0usize, 1, 63, 64, 65, 128, 1000, 4096] {
            for &c in &[1usize, 7, 64, 256] {
                let k = chunk_count(n, c);
                assert_eq!(k, n.div_ceil(c));
                let mut covered = 0;
                for i in 0..k {
                    let (s, e) = chunk_range(n, c, i);
                    assert_eq!(s, covered, "chunks must be contiguous (n={n} c={c} i={i})");
                    assert!(e > s && e <= n);
                    assert!(e - s <= c);
                    covered = e;
                }
                assert_eq!(covered, n, "chunks must cover the section (n={n} c={c})");
            }
        }
    }

    #[test]
    fn aligned_payload_has_full_chunks_only() {
        let xs = gen_f32s(9, 512);
        let dgs = section_digests(&xs, 128);
        assert_eq!(dgs.len(), 4);
        for i in 0..4 {
            let (s, e) = chunk_range(512, 128, i);
            assert_eq!(e - s, 128);
            assert_eq!(dgs[i], digest_f32(&xs[s..e]));
        }
    }

    #[test]
    fn dedup_identity_same_tensor_same_digests() {
        let xs = gen_f32s(42, 777);
        let ys = xs.clone();
        assert_eq!(section_digests(&xs, 100), section_digests(&ys, 100));
        // Repeated content chunks collide by design (that's the dedup).
        let rep = vec![1.5f32; 300];
        let dgs = section_digests(&rep, 100);
        assert_eq!(dgs[0], dgs[1]);
        assert_eq!(dgs[1], dgs[2]);
    }

    #[test]
    fn every_single_bit_corruption_changes_the_digest() {
        // Flip every bit of a small chunk's byte image and assert the
        // digest always moves — a fetched chunk with any bit flipped is
        // rejected by the verify step.
        let xs = gen_f32s(7, 12);
        let clean = digest_f32(&xs);
        let mut bytes: Vec<u8> = xs.iter().flat_map(|x| x.to_le_bytes()).collect();
        for bit in 0..bytes.len() * 8 {
            bytes[bit / 8] ^= 1 << (bit % 8);
            let corrupt: Vec<f32> = bytes
                .chunks_exact(4)
                .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                .collect();
            assert_ne!(
                digest_f32(&corrupt),
                clean,
                "bit {bit} flip must change the digest"
            );
            bytes[bit / 8] ^= 1 << (bit % 8);
        }
    }
}
