//! Heartbeat / lease failure detection.
//!
//! Every rank of an elastic epoch runs one monitor thread over a
//! reserved `TagMux` channel (the mux's last tag).  The monitor beats
//! every `interval`, drains incoming beats without ever blocking
//! ([`Transport::try_recv`]), and declares a peer lost when its lease
//! (`4 × interval` by [`ElasticOpts::lease`](super::ElasticOpts::lease))
//! expires without a beat — recording the suspicion on the epoch's
//! [`FailBoard`](super::FailBoard) and *severing* the link
//! ([`Transport::sever`]), which over TCP force-closes the socket so a
//! training thread blocked on the stalled peer fails instead of
//! hanging.  On `LocalFabric` sever is a no-op, but there a dead peer's
//! channels fail immediately anyway; only silent stalls stay invisible,
//! and in-process a stalled thread stalls the whole process clock too.
//!
//! The monitor never blocks on the fabric: sends are
//! [`Transport::send_checked`] (a dead peer is a suspicion, not a
//! panic) and receives are polls.  A frozen process (the `--stall-rank`
//! injection models SIGSTOP) freezes its monitor with it, so peers see
//! the beats stop — the property the eviction tests pin.

use super::FailBoard;
use crate::collectives::mux::TagChannel;
use crate::collectives::transport::{PeerLostCause, Transport};
use crate::obs::{self, SpanRing};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

/// Shared freeze switch for fault injection: while set to a future
/// deadline (millis from `origin`), the monitor neither beats nor
/// drains — the whole "process" looks stopped to its peers.
pub struct Freezer {
    origin: Instant,
    until_ms: AtomicU64,
}

impl Freezer {
    pub fn new() -> Freezer {
        Freezer { origin: Instant::now(), until_ms: AtomicU64::new(0) }
    }

    /// Freeze for `d` from now (driver side, before it sleeps itself).
    pub fn freeze_for(&self, d: Duration) {
        let until = self.origin.elapsed() + d;
        self.until_ms.store(until.as_millis() as u64, Ordering::Relaxed);
    }

    pub fn frozen(&self) -> bool {
        (self.origin.elapsed().as_millis() as u64) < self.until_ms.load(Ordering::Relaxed)
    }
}

impl Default for Freezer {
    fn default() -> Self {
        Freezer::new()
    }
}

/// Handle to a running monitor: set `stop` and the thread exits within
/// one beat interval (the epoch scope joins it).
pub struct MonitorHandle {
    pub stop: Arc<AtomicBool>,
}

impl MonitorHandle {
    pub fn stop(&self) {
        self.stop.store(true, Ordering::Relaxed);
    }
}

/// Spawn the epoch's monitor on `scope`.  `chan` is the reserved
/// heartbeat channel (group-local peer ids); `board` the epoch's
/// failure record; `freezer` the fault-injection switch; `ring` the
/// heartbeat lane's span ring when tracing is on (each beat sweep
/// records one `heartbeat` span, so the timeline shows the detector's
/// cadence next to the training lanes).
pub fn spawn_monitor<'scope, T>(
    scope: &'scope thread::Scope<'scope, '_>,
    chan: TagChannel<T>,
    board: Arc<FailBoard>,
    freezer: Arc<Freezer>,
    interval: Duration,
    lease: Duration,
    ring: Option<SpanRing>,
) -> MonitorHandle
where
    T: Transport + Send + Sync + 'scope,
{
    let stop = Arc::new(AtomicBool::new(false));
    let flag = Arc::clone(&stop);
    scope.spawn(move || {
        let me = chan.rank();
        let world = chan.world();
        let mut last_seen = vec![Instant::now(); world];
        let mut sweep = 0u32;
        loop {
            if flag.load(Ordering::Relaxed) {
                return;
            }
            if freezer.frozen() {
                // a stopped process beats no one and reads nothing
                thread::sleep(Duration::from_millis(1));
                continue;
            }
            let guard = ring.as_ref().map(|r| r.guard(obs::SPAN_HEARTBEAT, sweep, 0));
            sweep = sweep.wrapping_add(1);
            for peer in 0..world {
                if peer == me || board.is_suspect_local(peer) {
                    continue;
                }
                if let Err(e) = chan.send_checked(peer, vec![0x4842 /* "HB" */]) {
                    board.mark_local(peer, e.cause);
                    continue;
                }
                // drain every queued beat; anything from the peer counts
                // as liveness
                loop {
                    match chan.try_recv(peer) {
                        Ok(Some(_)) => last_seen[peer] = Instant::now(),
                        Ok(None) => break,
                        Err(e) => {
                            // out-of-band frames mean the peer entered
                            // reshape — alive, and the driver will see
                            // the parked frame; everything else is loss
                            if e.cause != PeerLostCause::OutOfBand {
                                board.mark_local(peer, e.cause);
                            } else {
                                last_seen[peer] = Instant::now();
                            }
                            break;
                        }
                    }
                }
                if last_seen[peer].elapsed() > lease && !board.is_suspect_local(peer) {
                    board.mark_local(peer, PeerLostCause::Timeout);
                    // convert a silent stall into a hard failure the
                    // blocked training thread can observe (TCP; no-op on
                    // the local fabric)
                    chan.sever(peer);
                }
            }
            drop(guard);
            thread::sleep(interval);
        }
    });
    MonitorHandle { stop }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::mux::TagMux;
    use crate::collectives::LocalFabric;

    #[test]
    fn freezer_gates_on_time() {
        let f = Freezer::new();
        assert!(!f.frozen());
        f.freeze_for(Duration::from_millis(50));
        assert!(f.frozen());
        thread::sleep(Duration::from_millis(80));
        assert!(!f.frozen());
    }

    #[test]
    fn monitor_stays_quiet_while_peers_beat() {
        let world = 2;
        let mut fabric = LocalFabric::new(world);
        let ts: Vec<_> = fabric.take_all();
        let boards: Vec<_> =
            (0..world).map(|_| Arc::new(FailBoard::new((0..world).collect()))).collect();
        let interval = Duration::from_millis(5);
        let lease = Duration::from_millis(200);
        thread::scope(|s| {
            let handles: Vec<MonitorHandle> = ts
                .iter()
                .zip(&boards)
                .map(|(t, b)| {
                    let mux = Arc::new(TagMux::new(t, 1));
                    let chan = TagChannel::new(mux, 0);
                    spawn_monitor(
                        s,
                        chan,
                        Arc::clone(b),
                        Arc::new(Freezer::new()),
                        interval,
                        lease,
                        None,
                    )
                })
                .collect();
            thread::sleep(Duration::from_millis(60));
            for h in &handles {
                h.stop();
            }
        });
        for b in &boards {
            assert!(!b.has_suspects(), "healthy peers must not be suspected");
        }
    }

    #[test]
    fn monitor_suspects_a_dead_peer() {
        let world = 2;
        let mut fabric = LocalFabric::new(world);
        let mut ts = fabric.take_all();
        let dead = ts.pop().unwrap(); // rank 1 never starts a monitor
        let t0 = ts.pop().unwrap();
        let board = Arc::new(FailBoard::new(vec![0, 1]));
        thread::scope(|s| {
            let mux = Arc::new(TagMux::new(&t0, 1));
            let chan = TagChannel::new(mux, 0);
            let h = spawn_monitor(
                s,
                chan,
                Arc::clone(&board),
                Arc::new(Freezer::new()),
                Duration::from_millis(5),
                Duration::from_millis(40),
                None,
            );
            drop(dead); // rank 1 dies: the next beat send fails
            let deadline = Instant::now() + Duration::from_secs(5);
            while !board.has_suspects() && Instant::now() < deadline {
                thread::sleep(Duration::from_millis(5));
            }
            h.stop();
        });
        let suspects = board.suspects();
        assert_eq!(suspects.len(), 1, "{suspects:?}");
        assert_eq!(suspects[0].0, 1);
    }
}
