//! The elastic training driver: the per-rank epoch/step loop that keeps
//! a compressed-sync run alive across membership changes.
//!
//! One *membership epoch* is a stretch of steps under a fixed view.
//! Per epoch the driver builds the full elastic stack over the raw
//! fabric endpoint:
//!
//! ```text
//! raw Transport (world ranks, lives across epochs)
//!   └─ ProcessGroup(view members)     rank translation for the view
//!        └─ Watched                   failure recording → FailBoard
//!             └─ TagMux               ctrl tag 0 | bucket tags | hb tag
//!                  ├─ TagChannel ctrl   dense/loss collectives (+ the
//!                  │                    Sequential engine's buckets)
//!                  ├─ bucket channels   Pipelined engine comm pool
//!                  └─ TagChannel hb     heartbeat monitor thread
//! ```
//!
//! Every completed step pushes a full state snapshot (params, per-layer
//! residual/momentum from the engine, dense velocities) into a
//! two-deep ring; bulk-synchronous steps keep ranks within one step of
//! each other, so the ring always covers the reshape's agreed resume
//! step.  A step that dies mid-collective (peer loss panics by the
//! transport contract) is caught, classified against the epoch's
//! `FailBoard` and parked out-of-band frames, and resolved by
//! [`reshape::agree`](super::reshape::agree); survivors roll back to
//! the agreed snapshot and rebuild the whole stack for the shrunken
//! view — bit-identically to a fresh run started from that snapshot,
//! which is exactly what `tests/elastic.rs` pins.
//!
//! The model side is abstracted behind [`Workload`], so the driver runs
//! artifact-free under tests/benches and with the real PJRT step
//! runner under `coordinator::worker`.

use super::heartbeat::{spawn_monitor, Freezer};
use super::reshape::{agree, Agreement};
use super::{derive_topology, FailBoard, FaultSpec, StallSpec, Watched, MAX_ELASTIC_WORLD};
use crate::collectives::group::{Algo, ProcessGroup, Topology};
use crate::collectives::mux::{TagChannel, TagMux};
use crate::collectives::transport::{f32s_to_words, words_to_f32s};
use crate::collectives::{allgather, allreduce_mean, Transport};
use crate::compression::{CompressorConfig, Method};
use crate::coordinator::checkpoint::{Checkpoint, LayerState};
use crate::coordinator::metrics::{param_hash, phase, MembershipEvent};
use crate::obs;
use crate::optim::{clip_by_global_norm, local_clip_factor, DenseOptState, LrSchedule, Optimizer};
use crate::pipeline::{
    build_buckets, BucketDone, BucketState, LayerSpec, Pipelined, Sequential, SyncEngine,
    BUCKET_TAG_BASE, CTRL_TAG,
};
use crate::util::timer::PhaseTimer;
use std::collections::{BTreeMap, VecDeque};
use std::panic::{self, AssertUnwindSafe};
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

/// Which shard of the data stream a step consumes: group-local rank and
/// view size plus the membership epoch — the `(seed, view_epoch, rank)`
/// re-keying that keeps shards disjoint across reshapes.
#[derive(Clone, Copy, Debug)]
pub struct ShardKey {
    pub epoch: u64,
    pub rank: usize,
    pub world: usize,
    pub step: usize,
}

/// The model side of a step: everything the elastic driver does *not*
/// own.  Implementations must be deterministic in `(params, key)` — the
/// root of the post-reshape bit-identity guarantee.
pub trait Workload {
    /// Forward/backward on this rank's shard: `(loss, per-layer grads)`
    /// in schema layer order.
    fn compute(&mut self, params: &[Vec<f32>], key: &ShardKey)
        -> Result<(f32, Vec<Vec<f32>>), String>;
}

/// Scheduled rejoin of a previously lost rank, executed at the start of
/// a fresh fabric generation (`orchestrate::run_local_fleet`): the
/// donor streams its current parameter image to the rejoiner over the
/// control channel (the "delta" advancing the rejoiner's checkpoint to
/// the barrier step); residual/momentum/velocity stay the rejoiner's
/// own checkpointed per-rank state.
#[derive(Clone, Copy, Debug)]
pub struct JoinPlan {
    pub rejoiner: usize,
    pub donor: usize,
    pub resume_step: usize,
    pub epoch: u64,
}

/// Everything the elastic driver needs beyond the transport and the
/// workload.  Mirrors the `config::ElasticConfig` + run knobs; kept
/// separate so tests and benches drive the subsystem without a full
/// `TrainConfig`.
#[derive(Clone, Debug)]
pub struct ElasticOpts {
    pub steps: usize,
    pub density: f64,
    pub lr: LrSchedule,
    pub clip: Option<f32>,
    pub optimizer: Optimizer,
    pub fusion_cap_elems: usize,
    pub pipeline: bool,
    pub inflight: usize,
    pub topology: Option<Topology>,
    /// Run every bucket's collective on the hierarchical schedule.
    pub hierarchical: bool,
    pub log_every: usize,
    /// Heartbeat interval; the lease is 4× this.
    pub heartbeat: Duration,
    pub min_ranks: usize,
    pub kill: Vec<FaultSpec>,
    pub stall: Vec<StallSpec>,
    /// Scheduled rejoins (rank, step) — `orchestrate` pauses the fleet
    /// at the step barrier and restarts a full-world generation.
    pub rejoin: Vec<FaultSpec>,
    /// Path prefix for `RSCK` files (periodic `{prefix}_rank{R}.rsck`,
    /// reshape dumps `{prefix}_reshape_e{E}_rank{R}.rsck`, the
    /// rejoiner's `{prefix}_join_rank{R}.rsck` — `R` always the world
    /// rank, so disjoint views never clobber each other).
    pub ckpt_prefix: Option<String>,
    /// Write a periodic checkpoint every this many steps (0 = never).
    pub ckpt_every: usize,
    pub cc: CompressorConfig,
}

impl Default for ElasticOpts {
    fn default() -> Self {
        ElasticOpts {
            steps: 10,
            density: 0.02,
            lr: LrSchedule::Constant { lr: 0.05 },
            clip: None,
            optimizer: Optimizer::Momentum { momentum: 0.9 },
            fusion_cap_elems: 0,
            pipeline: false,
            inflight: 2,
            topology: None,
            hierarchical: false,
            log_every: 1,
            heartbeat: Duration::from_millis(25),
            min_ranks: 1,
            kill: Vec::new(),
            stall: Vec::new(),
            rejoin: Vec::new(),
            ckpt_prefix: None,
            ckpt_every: 0,
            cc: CompressorConfig::default(),
        }
    }
}

impl ElasticOpts {
    /// The failure-detection lease: a peer silent this long is lost.
    pub fn lease(&self) -> Duration {
        self.heartbeat * 4
    }
}

/// How a rank's participation ended.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElasticStatus {
    /// Ran to `opts.steps` and passed the final replica-hash exchange.
    Finished,
    /// Died by fault injection (`--kill-rank`).
    Killed,
    /// Excluded from the surviving view (crash suspicion or quorum loss).
    Evicted,
    /// Stopped at a scheduled rejoin barrier; the orchestrator restarts
    /// a full-world generation from the returned state.
    Paused,
}

/// One rank's result: metrics plus the final state checkpoint.
pub struct RankOutcome {
    pub status: ElasticStatus,
    /// State at the last completed step boundary.
    pub state: Checkpoint,
    pub events: Vec<MembershipEvent>,
    pub loss_curve: Vec<(usize, f32)>,
    pub timer: PhaseTimer,
    pub param_hash: u64,
    pub final_loss: f32,
    /// Replica hashes agreed across the final view (`Finished` only).
    pub replicas_consistent: bool,
    /// Multiplexed traffic across the rank's epochs: total messages and
    /// words, and the non-bucket (control + heartbeat) share of words.
    pub mux_messages: u64,
    pub mux_words: u64,
    pub ctrl_words: u64,
    /// Final view (world ranks) and epoch.
    pub view: Vec<usize>,
    pub epoch: u64,
}

/// Build the step-0 state for a fresh rank: zero residual/momentum for
/// every compressed layer, zero velocity for dense layers under a
/// momentum-family optimizer.
pub fn fresh_checkpoint(
    params: Vec<Vec<f32>>,
    specs: &[LayerSpec],
    opt: Optimizer,
    seed: u64,
) -> Checkpoint {
    assert_eq!(params.len(), specs.len(), "one spec per layer");
    let layers = params
        .into_iter()
        .zip(specs)
        .map(|(p, s)| {
            let n = p.len();
            assert_eq!(n, s.n, "layer {} size", s.li);
            let residual =
                (s.method != Method::Dense).then(|| (vec![0.0; n], vec![0.0; n]));
            let velocity =
                (s.method == Method::Dense && opt != Optimizer::Sgd).then(|| vec![0.0; n]);
            LayerState { params: p, residual, velocity }
        })
        .collect();
    Checkpoint { step: 0, seed, view_epoch: 0, layers }
}

/// Mutable training state between snapshots.
struct TrainState {
    params: Vec<Vec<f32>>,
    dense: Vec<DenseOptState>,
    done: usize,
    epoch: u64,
}

fn state_from_checkpoint(
    ck: &Checkpoint,
    specs: &[LayerSpec],
    opt: Optimizer,
) -> Result<TrainState, String> {
    if ck.layers.len() != specs.len() {
        return Err(format!(
            "checkpoint has {} layers, model has {}",
            ck.layers.len(),
            specs.len()
        ));
    }
    let mut params = Vec::with_capacity(specs.len());
    let mut dense = Vec::with_capacity(specs.len());
    for (i, (l, s)) in ck.layers.iter().zip(specs).enumerate() {
        // the driver's convention throughout: specs are in schema order,
        // so a spec's layer id is its position
        assert_eq!(s.li, i, "elastic specs must be in schema order");
        if l.params.len() != s.n {
            return Err(format!(
                "checkpoint layer {} has {} params, want {}",
                s.li,
                l.params.len(),
                s.n
            ));
        }
        params.push(l.params.clone());
        let mut d = DenseOptState::new(s.n, opt);
        if let Some(vel) = &l.velocity {
            d.load_velocity(vel);
        }
        dense.push(d);
    }
    Ok(TrainState { params, dense, done: ck.step as usize, epoch: ck.view_epoch })
}

/// Full state snapshot at a step boundary: params + dense velocities
/// from `state`, residual/momentum from the engine's buckets.
///
/// This clones the full model state — O(model) heap traffic per step,
/// a deliberate trade for rollback simplicity at the scales the elastic
/// runs target.  If elastic steady-state allocation ever matters, the
/// evicted ring slot's buffers can be recycled (`copy_from_slice` into
/// the existing `Vec`s) without changing any semantics.
fn make_snapshot(
    state: &TrainState,
    engine: &dyn SyncEngine,
    specs: &[LayerSpec],
    seed: u64,
) -> Checkpoint {
    let mut residuals: BTreeMap<usize, (Vec<f32>, Vec<f32>)> = engine
        .export_layer_states()
        .into_iter()
        .map(|(li, v, u)| (li, (v, u)))
        .collect();
    let layers = specs
        .iter()
        .map(|s| LayerState {
            params: state.params[s.li].clone(),
            residual: residuals.remove(&s.li),
            velocity: if s.method == Method::Dense {
                state.dense[s.li].velocity().map(|v| v.to_vec())
            } else {
                None
            },
        })
        .collect();
    Checkpoint { step: state.done as u64, seed, view_epoch: state.epoch, layers }
}

/// Two-deep snapshot ring: bulk-synchronous steps keep every member
/// within one completed step of the others, so the reshape's agreed
/// resume step is always the latest or the previous boundary.
struct SnapRing {
    slots: VecDeque<(usize, Checkpoint)>,
}

impl SnapRing {
    fn new() -> SnapRing {
        SnapRing { slots: VecDeque::new() }
    }

    fn reset(&mut self, done: usize, ck: Checkpoint) {
        self.slots.clear();
        self.slots.push_back((done, ck));
    }

    fn push(&mut self, done: usize, ck: Checkpoint) {
        if self.slots.len() == 2 {
            self.slots.pop_front();
        }
        self.slots.push_back((done, ck));
    }

    fn get(&self, done: usize) -> Option<&Checkpoint> {
        self.slots.iter().find(|(d, _)| *d == done).map(|(_, c)| c)
    }

    fn latest(&self) -> &Checkpoint {
        &self.slots.back().expect("snapshot ring never empty").1
    }
}

/// Compressed-layer buckets for one epoch, residuals seeded from `ck`.
fn build_epoch_buckets(
    specs: &[LayerSpec],
    opts: &ElasticOpts,
    ck: &Checkpoint,
) -> Vec<BucketState> {
    let comp: Vec<LayerSpec> = specs
        .iter()
        .rev()
        .filter(|s| s.method != Method::Dense)
        .cloned()
        .collect();
    let mut buckets =
        build_buckets(&comp, opts.fusion_cap_elems, opts.optimizer.accumulation());
    for b in &mut buckets {
        if opts.hierarchical {
            b.set_algo(Algo::Hierarchical);
        }
        let lis: Vec<usize> = b.specs().map(|s| s.li).collect();
        for (idx, li) in lis.into_iter().enumerate() {
            if let Some((v, u)) = &ck.layers[li].residual {
                b.load_layer_state(idx, v, u);
            }
        }
    }
    buckets
}

/// How one epoch ended, as seen from inside its scope.
enum EpochMark {
    Finished { consistent: bool },
    Paused,
    Killed,
    Fault,
}

/// How one epoch ended, with the fault context the reshape needs.
enum EpochEnd {
    Finished { consistent: bool },
    Paused,
    Killed,
    Fault {
        suspects: Vec<usize>,
        /// Parked out-of-band frames, indexed by *world* rank.
        pending: Vec<VecDeque<Vec<u32>>>,
        detect_secs: f64,
    },
}

/// Run one rank through a full elastic job: epochs of steps separated
/// by reshapes, until completion, injected death, eviction or a rejoin
/// barrier.  `transport` is the raw fabric endpoint; `specs` all model
/// layers in schema order (dense and compressed); `init` the starting
/// state (fresh, resumed or generation-carried).
pub fn run_elastic_worker<T, W>(
    transport: &T,
    specs: &[LayerSpec],
    init: Checkpoint,
    join: Option<JoinPlan>,
    opts: &ElasticOpts,
    workload: &mut W,
) -> Result<RankOutcome, String>
where
    T: Transport + Sync,
    W: Workload,
{
    let my = transport.rank();
    let world0 = transport.world();
    assert!(world0 <= MAX_ELASTIC_WORLD, "elastic views are capped at {MAX_ELASTIC_WORLD} ranks");
    let seed = init.seed;
    let mut cur = init;
    if let Some(j) = &join {
        cur.view_epoch = j.epoch;
        cur.step = j.resume_step as u64;
    }
    let mut state = state_from_checkpoint(&cur, specs, opts.optimizer)?;
    let mut members: Vec<usize> = (0..world0).collect();
    let mut events: Vec<MembershipEvent> = Vec::new();
    if let Some(j) = &join {
        events.push(MembershipEvent {
            epoch: j.epoch,
            lost: Vec::new(),
            joined: vec![j.rejoiner],
            detect_secs: 0.0,
            reshape_secs: 0.0,
            resume_step: j.resume_step,
            world_after: world0,
        });
    }
    let mut curves: Vec<(usize, f32)> = Vec::new();
    let mut timer = PhaseTimer::new();
    let mut ring = SnapRing::new();
    let freezer = Arc::new(Freezer::new());
    let mut stall_used = vec![false; opts.stall.len()];
    let mut totals = (0u64, 0u64, 0u64); // (messages, words, non-bucket words)
    let mut final_loss = f32::NAN;
    let mut join_once = join;
    // driver lane: retrospective fault-detection spans and the reshape
    // stall, so the timeline shows why training paused
    let drv_ring = obs::enabled().then(|| obs::ring(my, obs::LANE_DRIVER, obs::DEFAULT_CAP));

    let outcome = |status: ElasticStatus,
                   consistent: bool,
                   state: &TrainState,
                   ring: &SnapRing,
                   events: Vec<MembershipEvent>,
                   curves: Vec<(usize, f32)>,
                   timer: PhaseTimer,
                   totals: (u64, u64, u64),
                   members: Vec<usize>,
                   final_loss: f32| RankOutcome {
        status,
        state: ring.latest().clone(),
        events,
        loss_curve: curves,
        timer,
        param_hash: param_hash(&state.params),
        final_loss,
        replicas_consistent: consistent,
        mux_messages: totals.0,
        mux_words: totals.1,
        ctrl_words: totals.2,
        view: members,
        epoch: state.epoch,
    };

    loop {
        if members.len() < opts.min_ranks.max(1) {
            return Err(format!(
                "rank {my}: view shrank to {} ranks, below --min-ranks {}",
                members.len(),
                opts.min_ranks
            ));
        }
        let end = run_epoch(
            transport,
            &members,
            specs,
            opts,
            seed,
            &cur,
            &mut state,
            &mut ring,
            join_once.take(),
            &mut curves,
            &mut timer,
            &freezer,
            &mut stall_used,
            &mut totals,
            &mut final_loss,
            workload,
        )?;
        match end {
            EpochEnd::Finished { consistent } => {
                return Ok(outcome(
                    ElasticStatus::Finished,
                    consistent,
                    &state,
                    &ring,
                    events,
                    curves,
                    timer,
                    totals,
                    members,
                    final_loss,
                ));
            }
            EpochEnd::Paused => {
                return Ok(outcome(
                    ElasticStatus::Paused,
                    false,
                    &state,
                    &ring,
                    events,
                    curves,
                    timer,
                    totals,
                    members,
                    final_loss,
                ));
            }
            EpochEnd::Killed => {
                return Ok(outcome(
                    ElasticStatus::Killed,
                    false,
                    &state,
                    &ring,
                    events,
                    curves,
                    timer,
                    totals,
                    members,
                    final_loss,
                ));
            }
            EpochEnd::Fault { suspects, pending, detect_secs } => {
                let t0 = Instant::now();
                if let Some(r) = &drv_ring {
                    // retrospective: detection ran from the last healthy
                    // step boundary until the fault surfaced (now)
                    let now = obs::now_us();
                    r.record(obs::Span {
                        phase: obs::SPAN_DETECT,
                        step: state.done as u32,
                        tag: state.epoch as u32,
                        t0_us: now.saturating_sub((detect_secs * 1e6) as u64),
                        t1_us: now,
                    });
                }
                let reshape_guard = drv_ring
                    .as_ref()
                    .map(|r| r.guard(obs::SPAN_RESHAPE, state.done as u32, state.epoch as u32));
                let agreement = agree(
                    transport,
                    my,
                    &members,
                    state.epoch,
                    &suspects,
                    state.done,
                    pending,
                    opts.lease(),
                    opts.min_ranks,
                )?;
                drop(reshape_guard);
                match agreement {
                    Agreement::Evicted(why) => {
                        crate::log_warn!("rank {my}: evicted from the view: {why}");
                        return Ok(outcome(
                            ElasticStatus::Evicted,
                            false,
                            &state,
                            &ring,
                            events,
                            curves,
                            timer,
                            totals,
                            members,
                            final_loss,
                        ));
                    }
                    Agreement::View { members: next, epoch, resume_step } => {
                        let snap = ring
                            .get(resume_step)
                            .ok_or_else(|| {
                                format!(
                                    "rank {my}: rollback snapshot for step {resume_step} \
                                     missing (have up to {})",
                                    state.done
                                )
                            })?
                            .clone();
                        let lost: Vec<usize> =
                            members.iter().copied().filter(|r| !next.contains(r)).collect();
                        events.push(MembershipEvent {
                            epoch,
                            lost,
                            joined: Vec::new(),
                            detect_secs,
                            reshape_secs: t0.elapsed().as_secs_f64(),
                            resume_step,
                            world_after: next.len(),
                        });
                        cur = snap;
                        cur.view_epoch = epoch;
                        cur.step = resume_step as u64;
                        state = state_from_checkpoint(&cur, specs, opts.optimizer)?;
                        curves.retain(|&(s, _)| s < resume_step);
                        members = next;
                        // dump the rollback state so a fresh shrunken-world
                        // run can be started (and bit-compared) from it —
                        // keyed by *world* rank, so disjoint views (a
                        // solo-partitioned rank under --min-ranks 1) can
                        // never clobber each other's files
                        if let Some(prefix) = &opts.ckpt_prefix {
                            let path = format!("{prefix}_reshape_e{epoch}_rank{my}.rsck");
                            cur.save(&path).map_err(|e| format!("reshape ckpt: {e}"))?;
                        }
                    }
                }
            }
        }
    }
}

/// One membership epoch: build the stack, run steps until the job ends
/// or a fault breaks the view.
#[allow(clippy::too_many_arguments)]
fn run_epoch<T, W>(
    transport: &T,
    members: &[usize],
    specs: &[LayerSpec],
    opts: &ElasticOpts,
    seed: u64,
    cur: &Checkpoint,
    state: &mut TrainState,
    ring: &mut SnapRing,
    join: Option<JoinPlan>,
    curves: &mut Vec<(usize, f32)>,
    timer: &mut PhaseTimer,
    freezer: &Arc<Freezer>,
    stall_used: &mut [bool],
    totals: &mut (u64, u64, u64),
    final_loss: &mut f32,
    workload: &mut W,
) -> Result<EpochEnd, String>
where
    T: Transport + Sync,
    W: Workload,
{
    let my = transport.rank();
    let k = members.len();
    let me_local = members.iter().position(|&m| m == my).expect("rank is a view member");
    let group = ProcessGroup::new(transport, members.to_vec());
    let board = Arc::new(FailBoard::new(members.to_vec()));
    let fabric = Watched::new(group, Arc::clone(&board));
    let topo = derive_topology(opts.topology, members);
    let buckets = build_epoch_buckets(specs, opts, cur);
    let n_buckets = buckets.len();
    let n_tags =
        if opts.pipeline { BUCKET_TAG_BASE as usize + n_buckets + 1 } else { 2 };
    let hb_tag = (n_tags - 1) as u32;
    // the heartbeat tag is the mux's side channel: beats stay visible
    // to the monitor's poll even while a collective blocks on the peer
    // (otherwise a step longer than the lease would read as death)
    let mux = Arc::new(TagMux::with_side_channel(fabric, n_tags as u32, hb_tag));
    let ctrl = TagChannel::new(Arc::clone(&mux), CTRL_TAG);
    let hb = TagChannel::new(Arc::clone(&mux), hb_tag);

    // per-epoch span rings, keyed by the *world* rank so the per-rank
    // trace export finds them; engine-registered rings use the group-
    // local rank and are swept up by the same export
    let epoch_ring = obs::enabled().then(|| obs::ring(my, obs::LANE_MAIN, obs::DEFAULT_CAP));
    let hb_ring = obs::enabled().then(|| obs::ring(my, obs::LANE_HEARTBEAT, obs::DEFAULT_CAP));

    let mut last_ok = Instant::now();
    let mark: Result<EpochMark, String> = thread::scope(|s| {
        let monitor = spawn_monitor(
            s,
            hb.clone(),
            Arc::clone(&board),
            Arc::clone(freezer),
            opts.heartbeat,
            opts.lease(),
            hb_ring,
        );
        let run = (|| -> Result<EpochMark, String> {
            let mut seq_engine;
            let mut pipe_engine;
            let engine: &mut dyn SyncEngine = if opts.pipeline {
                pipe_engine = Pipelined::with_topology(
                    Arc::clone(&mux),
                    topo,
                    buckets,
                    opts.inflight,
                    opts.cc,
                );
                &mut pipe_engine
            } else {
                seq_engine = Sequential::with_topology(&ctrl, topo, None, buckets, opts.cc);
                &mut seq_engine
            };

            // rejoin barrier entry: the donor streams its parameter
            // image to the rejoiner before anyone steps
            if let Some(j) = &join {
                join_sync(&ctrl, members, me_local, j, state)?;
            }
            ring.reset(state.done, make_snapshot(state, &*engine, specs, seed));
            if let Some(j) = &join {
                if my == j.rejoiner {
                    if let Some(prefix) = &opts.ckpt_prefix {
                        let path = format!("{prefix}_join_rank{my}.rsck");
                        ring.latest().save(&path).map_err(|e| format!("join ckpt: {e}"))?;
                    }
                }
            }

            loop {
                let step = state.done;
                if step >= opts.steps {
                    let consistent =
                        match panic::catch_unwind(AssertUnwindSafe(|| {
                            replica_hashes_agree(&ctrl, &state.params)
                        })) {
                            Ok(c) => c,
                            Err(_) => {
                                monitor.stop();
                                return Ok(EpochMark::Fault);
                            }
                        };
                    monitor.stop();
                    return Ok(EpochMark::Finished { consistent });
                }
                if opts.kill.iter().any(|f| f.rank == my && f.step == step) {
                    crate::log_warn!("rank {my}: killed by fault injection at step {step}");
                    monitor.stop();
                    return Ok(EpochMark::Killed);
                }
                for (i, st) in opts.stall.iter().enumerate() {
                    if st.rank == my && st.step == step && !stall_used[i] {
                        stall_used[i] = true;
                        crate::log_warn!(
                            "rank {my}: stalling {}ms at step {step} (fault injection)",
                            st.millis
                        );
                        freezer.freeze_for(Duration::from_millis(st.millis));
                        thread::sleep(Duration::from_millis(st.millis));
                    }
                }
                if opts
                    .rejoin
                    .iter()
                    .any(|f| f.step == step && !members.contains(&f.rank))
                {
                    monitor.stop();
                    return Ok(EpochMark::Paused);
                }
                if board.has_suspects() || mux.has_oob() {
                    monitor.stop();
                    return Ok(EpochMark::Fault);
                }

                let step_guard =
                    epoch_ring.as_ref().map(|r| r.guard(obs::SPAN_STEP, step as u32, 0));
                let attempt = panic::catch_unwind(AssertUnwindSafe(|| {
                    run_step(
                        &ctrl,
                        &mut *engine,
                        specs,
                        opts,
                        &mut *state,
                        me_local,
                        k,
                        step,
                        &mut *timer,
                        &mut *curves,
                        &mut *final_loss,
                        &mut *workload,
                    )
                }));
                drop(step_guard);
                match attempt {
                    Ok(Ok(())) => {
                        state.done += 1;
                        last_ok = Instant::now();
                        ring.push(state.done, make_snapshot(state, &*engine, specs, seed));
                        if opts.ckpt_every > 0 && state.done % opts.ckpt_every == 0 {
                            if let Some(prefix) = &opts.ckpt_prefix {
                                let path = format!("{prefix}_rank{my}.rsck");
                                ring.latest()
                                    .save(&path)
                                    .map_err(|e| format!("periodic ckpt: {e}"))?;
                            }
                        }
                    }
                    Ok(Err(e)) => {
                        monitor.stop();
                        if board.has_suspects() || mux.has_oob() {
                            return Ok(EpochMark::Fault);
                        }
                        return Err(e);
                    }
                    Err(_) => {
                        monitor.stop();
                        if board.has_suspects() || mux.has_oob() {
                            return Ok(EpochMark::Fault);
                        }
                        return Err(format!(
                            "rank {my} step {step}: aborted without a recorded membership fault"
                        ));
                    }
                }
            }
        })();
        monitor.stop();
        run
    });

    // mux traffic accounting survives the epoch teardown; "control" is
    // everything that is not a bucket stream (ctrl collectives + beats)
    let (msgs, words) = mux.aggregate();
    totals.0 += msgs;
    totals.1 += words;
    totals.2 += (mux.tag_stats(CTRL_TAG).bytes() + mux.tag_stats(hb_tag).bytes()) / 4;

    match mark? {
        EpochMark::Finished { consistent } => Ok(EpochEnd::Finished { consistent }),
        EpochMark::Paused => Ok(EpochEnd::Paused),
        EpochMark::Killed => Ok(EpochEnd::Killed),
        EpochMark::Fault => {
            let detect_secs = last_ok.elapsed().as_secs_f64();
            let mut pending: Vec<VecDeque<Vec<u32>>> =
                (0..transport.world()).map(|_| VecDeque::new()).collect();
            for (local, q) in mux.drain_oob().into_iter().enumerate() {
                pending[members[local]] = q;
            }
            let suspects: Vec<usize> = board.suspects().into_iter().map(|(r, _)| r).collect();
            Ok(EpochEnd::Fault { suspects, pending, detect_secs })
        }
    }
}

/// One training step under the current view: compute → clip → dense
/// allreduce + update → compressed buckets through the engine →
/// loss logging.  Exactly the non-elastic worker's schedule, scoped to
/// the view's process group.
#[allow(clippy::too_many_arguments)]
fn run_step<C, W>(
    ctrl: &C,
    engine: &mut dyn SyncEngine,
    specs: &[LayerSpec],
    opts: &ElasticOpts,
    state: &mut TrainState,
    me_local: usize,
    k: usize,
    step: usize,
    timer: &mut PhaseTimer,
    curves: &mut Vec<(usize, f32)>,
    final_loss: &mut f32,
    workload: &mut W,
) -> Result<(), String>
where
    C: Transport,
    W: Workload,
{
    let lr = opts.lr.lr_at(step);
    let key = ShardKey { epoch: state.epoch, rank: me_local, world: k, step };
    let (loss, mut grads) =
        timer.time(phase::COMPUTE, || workload.compute(&state.params, &key))?;
    if grads.len() != specs.len() {
        return Err(format!("workload produced {} grads for {} layers", grads.len(), specs.len()));
    }

    if let Some(max_norm) = opts.clip {
        let any_compressed = specs.iter().any(|s| s.method != Method::Dense);
        let limit =
            if any_compressed { local_clip_factor(max_norm, k) } else { max_norm };
        let mut refs: Vec<&mut [f32]> = grads.iter_mut().map(|g| g.as_mut_slice()).collect();
        clip_by_global_norm(&mut refs, limit);
    }

    let scale = -lr / k as f32;
    for li in (0..specs.len()).rev() {
        if specs[li].method != Method::Dense {
            continue;
        }
        timer.time(phase::COMM_DENSE, || allreduce_mean(ctrl, &mut grads[li]));
        timer.time(phase::UPDATE, || {
            state.dense[li].apply(opts.optimizer, &mut state.params[li], &grads[li], lr)
        });
    }

    let mut unpack_secs = 0.0f64;
    {
        let params = &mut state.params;
        let mut apply = |done: BucketDone| -> Result<(), String> {
            let t0 = Instant::now();
            done.apply_to(params, scale)?;
            unpack_secs += t0.elapsed().as_secs_f64();
            Ok(())
        };
        engine.sync_step(&grads, opts.density, timer, &mut apply)?;
    }
    timer.add(phase::UNPACK, unpack_secs);

    let log_step = step % opts.log_every.max(1) == 0 || step + 1 == opts.steps;
    if log_step {
        let mut l = [loss];
        allreduce_mean(ctrl, &mut l);
        if me_local == 0 {
            curves.push((step, l[0]));
        }
    }
    *final_loss = loss;
    Ok(())
}

/// The rejoin "delta" stream: the donor sends every layer's current
/// parameter words to the rejoiner on the control channel; the rejoiner
/// overwrites its (checkpoint-stale) parameters.  Per-link FIFO puts
/// these frames ahead of the donor's first step traffic, so no barrier
/// is needed for the other members.
fn join_sync<C: Transport>(
    ctrl: &C,
    members: &[usize],
    me_local: usize,
    j: &JoinPlan,
    state: &mut TrainState,
) -> Result<(), String> {
    let donor_local = members
        .iter()
        .position(|&r| r == j.donor)
        .ok_or_else(|| format!("join donor {} not in the view", j.donor))?;
    let join_local = members
        .iter()
        .position(|&r| r == j.rejoiner)
        .ok_or_else(|| format!("rejoiner {} not in the view", j.rejoiner))?;
    if me_local == donor_local {
        for p in &state.params {
            ctrl.send(join_local, f32s_to_words(p));
        }
    } else if me_local == join_local {
        for li in 0..state.params.len() {
            let words = ctrl
                .recv_checked(donor_local)
                .map_err(|e| format!("join sync layer {li}: {e}"))?;
            let vals = words_to_f32s(&words);
            if vals.len() != state.params[li].len() {
                return Err(format!(
                    "join sync layer {li}: got {} params, want {}",
                    vals.len(),
                    state.params[li].len()
                ));
            }
            state.params[li] = vals;
        }
    }
    Ok(())
}

/// Allgather the FNV parameter hashes across the view and compare.
fn replica_hashes_agree<C: Transport>(ctrl: &C, params: &[Vec<f32>]) -> bool {
    let h = param_hash(params);
    let msg = vec![(h & 0xFFFF_FFFF) as u32, (h >> 32) as u32];
    let all = allgather(ctrl, msg);
    all.iter().all(|w| w.len() == 2 && (w[0] as u64 | (w[1] as u64) << 32) == h)
}
