//! The elastic training driver: the per-rank epoch/step loop that keeps
//! a compressed-sync run alive across membership changes.
//!
//! One *membership epoch* is a stretch of steps under a fixed view.
//! Per epoch the driver builds the full elastic stack over the raw
//! fabric endpoint:
//!
//! ```text
//! raw Transport (world ranks, lives across epochs)
//!   └─ ProcessGroup(view members)     rank translation for the view
//!        └─ Watched                   failure recording → FailBoard
//!             └─ TagMux               ctrl tag 0 | bucket tags | hb tag
//!                  ├─ TagChannel ctrl   dense/loss collectives (+ the
//!                  │                    Sequential engine's buckets)
//!                  ├─ bucket channels   Pipelined engine comm pool
//!                  └─ TagChannel hb     heartbeat monitor thread
//! ```
//!
//! Every completed step pushes a full state snapshot (params, per-layer
//! residual/momentum from the engine, dense velocities) into a
//! two-deep ring; bulk-synchronous steps keep ranks within one step of
//! each other, so the ring always covers the reshape's agreed resume
//! step.  A step that dies mid-collective (peer loss panics by the
//! transport contract) is caught, classified against the epoch's
//! `FailBoard` and parked out-of-band frames, and resolved by
//! [`reshape::agree`](super::reshape::agree); survivors roll back to
//! the agreed snapshot and rebuild the whole stack for the shrunken
//! view — bit-identically to a fresh run started from that snapshot,
//! which is exactly what `tests/elastic.rs` pins.
//!
//! The model side is abstracted behind [`Workload`], so the driver runs
//! artifact-free under tests/benches and with the real PJRT step
//! runner under `coordinator::worker`.

use super::heartbeat::{spawn_monitor, Freezer};
use super::repo::CkptRepo;
use super::reshape::{agree, Agreement};
use super::{chunk, derive_topology, FailBoard, FaultSpec, StallSpec, Watched, MAX_ELASTIC_WORLD};
use crate::collectives::group::{Algo, ProcessGroup, Topology};
use crate::collectives::mux::{TagChannel, TagMux};
use crate::collectives::transport::{f32s_to_words, words_to_f32s};
use crate::collectives::{allgather, allreduce_mean, Transport};
use crate::compression::{CompressorConfig, Method};
use crate::coordinator::checkpoint::{Checkpoint, LayerState};
use crate::coordinator::metrics::{param_hash, phase, MembershipEvent, RejoinStats, RepoStats};
use crate::obs;
use crate::optim::{clip_by_global_norm, local_clip_factor, DenseOptState, LrSchedule, Optimizer};
use crate::pipeline::{
    build_buckets, BucketDone, BucketState, LayerSpec, Pipelined, Sequential, SyncEngine,
    BUCKET_TAG_BASE, CTRL_TAG,
};
use crate::util::timer::PhaseTimer;
use std::collections::{BTreeMap, VecDeque};
use std::panic::{self, AssertUnwindSafe};
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

/// Which shard of the data stream a step consumes: group-local rank and
/// view size plus the membership epoch — the `(seed, view_epoch, rank)`
/// re-keying that keeps shards disjoint across reshapes.
#[derive(Clone, Copy, Debug)]
pub struct ShardKey {
    pub epoch: u64,
    pub rank: usize,
    pub world: usize,
    pub step: usize,
}

/// The model side of a step: everything the elastic driver does *not*
/// own.  Implementations must be deterministic in `(params, key)` — the
/// root of the post-reshape bit-identity guarantee.
pub trait Workload {
    /// Forward/backward on this rank's shard: `(loss, per-layer grads)`
    /// in schema layer order.
    fn compute(&mut self, params: &[Vec<f32>], key: &ShardKey)
        -> Result<(f32, Vec<Vec<f32>>), String>;
}

/// Scheduled rejoin of a previously lost rank, executed at the start of
/// a fresh fabric generation (`orchestrate::run_local_fleet`): the
/// rejoiner diffs its (checkpoint-stale) parameter image against the
/// donors' resume manifest and fetches only the missing chunks over the
/// control channel, digest-verified and striped across every listed
/// donor with failover (DESIGN.md §Checkpoint-Repository) — or, with
/// `rejoin_full_image`, the legacy full parameter stream from
/// `donors[0]`.  Residual/momentum/velocity stay the rejoiner's own
/// checkpointed per-rank state.
#[derive(Clone, Debug)]
pub struct JoinPlan {
    pub rejoiner: usize,
    /// Surviving ranks that can serve resume-state chunks, in
    /// preference order; all replicas, so any subset suffices.
    pub donors: Vec<usize>,
    pub resume_step: usize,
    pub epoch: u64,
}

/// Everything the elastic driver needs beyond the transport and the
/// workload.  Mirrors the `config::ElasticConfig` + run knobs; kept
/// separate so tests and benches drive the subsystem without a full
/// `TrainConfig`.
#[derive(Clone, Debug)]
pub struct ElasticOpts {
    pub steps: usize,
    pub density: f64,
    pub lr: LrSchedule,
    pub clip: Option<f32>,
    pub optimizer: Optimizer,
    pub fusion_cap_elems: usize,
    pub pipeline: bool,
    pub inflight: usize,
    pub topology: Option<Topology>,
    /// Run every bucket's collective on the hierarchical schedule.
    pub hierarchical: bool,
    pub log_every: usize,
    /// Heartbeat interval; the lease is 4× this.
    pub heartbeat: Duration,
    pub min_ranks: usize,
    pub kill: Vec<FaultSpec>,
    pub stall: Vec<StallSpec>,
    /// Scheduled rejoins (rank, step) — `orchestrate` pauses the fleet
    /// at the step barrier and restarts a full-world generation.
    pub rejoin: Vec<FaultSpec>,
    /// Path prefix for `RSCK` files (periodic `{prefix}_rank{R}.rsck`,
    /// reshape dumps `{prefix}_reshape_e{E}_rank{R}.rsck`, the
    /// rejoiner's `{prefix}_join_rank{R}.rsck` — `R` always the world
    /// rank, so disjoint views never clobber each other).
    pub ckpt_prefix: Option<String>,
    /// Write a periodic checkpoint every this many steps (0 = never).
    pub ckpt_every: usize,
    /// Root of the per-rank content-addressed checkpoint repository
    /// (`{root}/rank{R}/…`); `None` disables the store and the delta
    /// rejoin's local chunk reuse.
    pub ckpt_repo: Option<String>,
    /// Chunk width (f32 elements) for the repository and the delta
    /// rejoin.
    pub chunk_elems: usize,
    /// How many donors a delta rejoin stripes its fetches across.
    pub rejoin_donors: usize,
    /// Use the legacy single-donor full parameter stream instead of the
    /// chunked delta protocol (the traffic baseline in tests/benches).
    pub rejoin_full_image: bool,
    /// Fault injection: these world ranks die after serving one chunk of
    /// a delta rejoin (mid-transfer donor loss).
    pub join_kill: Vec<usize>,
    /// Fault injection: these world ranks flip a bit in the first chunk
    /// they serve (exercises digest verification + retry).
    pub join_corrupt: Vec<usize>,
    pub cc: CompressorConfig,
}

impl Default for ElasticOpts {
    fn default() -> Self {
        ElasticOpts {
            steps: 10,
            density: 0.02,
            lr: LrSchedule::Constant { lr: 0.05 },
            clip: None,
            optimizer: Optimizer::Momentum { momentum: 0.9 },
            fusion_cap_elems: 0,
            pipeline: false,
            inflight: 2,
            topology: None,
            hierarchical: false,
            log_every: 1,
            heartbeat: Duration::from_millis(25),
            min_ranks: 1,
            kill: Vec::new(),
            stall: Vec::new(),
            rejoin: Vec::new(),
            ckpt_prefix: None,
            ckpt_every: 0,
            ckpt_repo: None,
            chunk_elems: chunk::DEFAULT_CHUNK_ELEMS,
            rejoin_donors: 2,
            rejoin_full_image: false,
            join_kill: Vec::new(),
            join_corrupt: Vec::new(),
            cc: CompressorConfig::default(),
        }
    }
}

impl ElasticOpts {
    /// The failure-detection lease: a peer silent this long is lost.
    pub fn lease(&self) -> Duration {
        self.heartbeat * 4
    }
}

/// How a rank's participation ended.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElasticStatus {
    /// Ran to `opts.steps` and passed the final replica-hash exchange.
    Finished,
    /// Died by fault injection (`--kill-rank`).
    Killed,
    /// Excluded from the surviving view (crash suspicion or quorum loss).
    Evicted,
    /// Stopped at a scheduled rejoin barrier; the orchestrator restarts
    /// a full-world generation from the returned state.
    Paused,
}

/// One rank's result: metrics plus the final state checkpoint.
pub struct RankOutcome {
    pub status: ElasticStatus,
    /// State at the last completed step boundary.
    pub state: Checkpoint,
    pub events: Vec<MembershipEvent>,
    pub loss_curve: Vec<(usize, f32)>,
    pub timer: PhaseTimer,
    pub param_hash: u64,
    pub final_loss: f32,
    /// Replica hashes agreed across the final view (`Finished` only).
    pub replicas_consistent: bool,
    /// Multiplexed traffic across the rank's epochs: total messages and
    /// words, and the non-bucket (control + heartbeat) share of words.
    pub mux_messages: u64,
    pub mux_words: u64,
    pub ctrl_words: u64,
    /// Final view (world ranks) and epoch.
    pub view: Vec<usize>,
    pub epoch: u64,
    /// Delta-rejoin accounting (all-zero when this rank saw no rejoin).
    pub rejoin: RejoinStats,
    /// Checkpoint-repository accounting (all-zero without `ckpt_repo`).
    pub repo: RepoStats,
}

/// Build the step-0 state for a fresh rank: zero residual/momentum for
/// every compressed layer, zero velocity for dense layers under a
/// momentum-family optimizer.
pub fn fresh_checkpoint(
    params: Vec<Vec<f32>>,
    specs: &[LayerSpec],
    opt: Optimizer,
    seed: u64,
) -> Checkpoint {
    assert_eq!(params.len(), specs.len(), "one spec per layer");
    let layers = params
        .into_iter()
        .zip(specs)
        .map(|(p, s)| {
            let n = p.len();
            assert_eq!(n, s.n, "layer {} size", s.li);
            let residual =
                (s.method != Method::Dense).then(|| (vec![0.0; n], vec![0.0; n]));
            let velocity =
                (s.method == Method::Dense && opt != Optimizer::Sgd).then(|| vec![0.0; n]);
            LayerState { params: p, residual, velocity }
        })
        .collect();
    Checkpoint { step: 0, seed, view_epoch: 0, layers }
}

/// Mutable training state between snapshots.
struct TrainState {
    params: Vec<Vec<f32>>,
    dense: Vec<DenseOptState>,
    done: usize,
    epoch: u64,
}

fn state_from_checkpoint(
    ck: &Checkpoint,
    specs: &[LayerSpec],
    opt: Optimizer,
) -> Result<TrainState, String> {
    if ck.layers.len() != specs.len() {
        return Err(format!(
            "checkpoint has {} layers, model has {}",
            ck.layers.len(),
            specs.len()
        ));
    }
    let mut params = Vec::with_capacity(specs.len());
    let mut dense = Vec::with_capacity(specs.len());
    for (i, (l, s)) in ck.layers.iter().zip(specs).enumerate() {
        // the driver's convention throughout: specs are in schema order,
        // so a spec's layer id is its position
        assert_eq!(s.li, i, "elastic specs must be in schema order");
        if l.params.len() != s.n {
            return Err(format!(
                "checkpoint layer {} has {} params, want {}",
                s.li,
                l.params.len(),
                s.n
            ));
        }
        params.push(l.params.clone());
        let mut d = DenseOptState::new(s.n, opt);
        if let Some(vel) = &l.velocity {
            d.load_velocity(vel);
        }
        dense.push(d);
    }
    Ok(TrainState { params, dense, done: ck.step as usize, epoch: ck.view_epoch })
}

/// Full state snapshot at a step boundary: params + dense velocities
/// from `state`, residual/momentum from the engine's buckets.
///
/// This clones the full model state — O(model) heap traffic per step,
/// a deliberate trade for rollback simplicity at the scales the elastic
/// runs target.  If elastic steady-state allocation ever matters, the
/// evicted ring slot's buffers can be recycled (`copy_from_slice` into
/// the existing `Vec`s) without changing any semantics.
fn make_snapshot(
    state: &TrainState,
    engine: &dyn SyncEngine,
    specs: &[LayerSpec],
    seed: u64,
) -> Checkpoint {
    let mut residuals: BTreeMap<usize, (Vec<f32>, Vec<f32>)> = engine
        .export_layer_states()
        .into_iter()
        .map(|(li, v, u)| (li, (v, u)))
        .collect();
    let layers = specs
        .iter()
        .map(|s| LayerState {
            params: state.params[s.li].clone(),
            residual: residuals.remove(&s.li),
            velocity: if s.method == Method::Dense {
                state.dense[s.li].velocity().map(|v| v.to_vec())
            } else {
                None
            },
        })
        .collect();
    Checkpoint { step: state.done as u64, seed, view_epoch: state.epoch, layers }
}

/// Two-deep snapshot ring: bulk-synchronous steps keep every member
/// within one completed step of the others, so the reshape's agreed
/// resume step is always the latest or the previous boundary.
struct SnapRing {
    slots: VecDeque<(usize, Checkpoint)>,
}

impl SnapRing {
    fn new() -> SnapRing {
        SnapRing { slots: VecDeque::new() }
    }

    fn reset(&mut self, done: usize, ck: Checkpoint) {
        self.slots.clear();
        self.slots.push_back((done, ck));
    }

    fn push(&mut self, done: usize, ck: Checkpoint) {
        if self.slots.len() == 2 {
            self.slots.pop_front();
        }
        self.slots.push_back((done, ck));
    }

    fn get(&self, done: usize) -> Option<&Checkpoint> {
        self.slots.iter().find(|(d, _)| *d == done).map(|(_, c)| c)
    }

    fn latest(&self) -> &Checkpoint {
        &self.slots.back().expect("snapshot ring never empty").1
    }
}

/// Compressed-layer buckets for one epoch, residuals seeded from `ck`.
fn build_epoch_buckets(
    specs: &[LayerSpec],
    opts: &ElasticOpts,
    ck: &Checkpoint,
) -> Vec<BucketState> {
    let comp: Vec<LayerSpec> = specs
        .iter()
        .rev()
        .filter(|s| s.method != Method::Dense)
        .cloned()
        .collect();
    let mut buckets =
        build_buckets(&comp, opts.fusion_cap_elems, opts.optimizer.accumulation());
    for b in &mut buckets {
        if opts.hierarchical {
            b.set_algo(Algo::Hierarchical);
        }
        let lis: Vec<usize> = b.specs().map(|s| s.li).collect();
        for (idx, li) in lis.into_iter().enumerate() {
            if let Some((v, u)) = &ck.layers[li].residual {
                b.load_layer_state(idx, v, u);
            }
        }
    }
    buckets
}

/// How one epoch ended, as seen from inside its scope.
enum EpochMark {
    Finished { consistent: bool },
    Paused,
    Killed,
    Fault,
}

/// How one epoch ended, with the fault context the reshape needs.
enum EpochEnd {
    Finished { consistent: bool },
    Paused,
    Killed,
    Fault {
        suspects: Vec<usize>,
        /// Parked out-of-band frames, indexed by *world* rank.
        pending: Vec<VecDeque<Vec<u32>>>,
        detect_secs: f64,
    },
}

/// Run one rank through a full elastic job: epochs of steps separated
/// by reshapes, until completion, injected death, eviction or a rejoin
/// barrier.  `transport` is the raw fabric endpoint; `specs` all model
/// layers in schema order (dense and compressed); `init` the starting
/// state (fresh, resumed or generation-carried).
pub fn run_elastic_worker<T, W>(
    transport: &T,
    specs: &[LayerSpec],
    init: Checkpoint,
    join: Option<JoinPlan>,
    opts: &ElasticOpts,
    workload: &mut W,
) -> Result<RankOutcome, String>
where
    T: Transport + Sync,
    W: Workload,
{
    let my = transport.rank();
    let world0 = transport.world();
    assert!(world0 <= MAX_ELASTIC_WORLD, "elastic views are capped at {MAX_ELASTIC_WORLD} ranks");
    let seed = init.seed;
    let mut cur = init;
    if let Some(j) = &join {
        cur.view_epoch = j.epoch;
        cur.step = j.resume_step as u64;
    }
    let mut state = state_from_checkpoint(&cur, specs, opts.optimizer)?;
    let mut members: Vec<usize> = (0..world0).collect();
    let mut events: Vec<MembershipEvent> = Vec::new();
    if let Some(j) = &join {
        events.push(MembershipEvent {
            epoch: j.epoch,
            lost: Vec::new(),
            joined: vec![j.rejoiner],
            detect_secs: 0.0,
            reshape_secs: 0.0,
            resume_step: j.resume_step,
            world_after: world0,
        });
    }
    let mut curves: Vec<(usize, f32)> = Vec::new();
    let mut timer = PhaseTimer::new();
    let mut ring = SnapRing::new();
    let freezer = Arc::new(Freezer::new());
    let mut stall_used = vec![false; opts.stall.len()];
    let mut totals = (0u64, 0u64, 0u64); // (messages, words, non-bucket words)
    let mut final_loss = f32::NAN;
    let mut rejoin_stats = RejoinStats::default();
    // the content-addressed store is per world rank: every snapshot the
    // ring takes is also put into the repository, deduped and refcounted
    let mut repo = match &opts.ckpt_repo {
        Some(root) => Some(
            CkptRepo::open(format!("{root}/rank{my}"), opts.chunk_elems.max(1), 2)
                .map_err(|e| format!("rank {my}: {e}"))?,
        ),
        None => None,
    };
    let mut join_once = join;
    // driver lane: retrospective fault-detection spans and the reshape
    // stall, so the timeline shows why training paused
    let drv_ring = obs::enabled().then(|| obs::ring(my, obs::LANE_DRIVER, obs::DEFAULT_CAP));

    let outcome = |status: ElasticStatus,
                   consistent: bool,
                   state: &TrainState,
                   ring: &SnapRing,
                   events: Vec<MembershipEvent>,
                   curves: Vec<(usize, f32)>,
                   timer: PhaseTimer,
                   totals: (u64, u64, u64),
                   members: Vec<usize>,
                   final_loss: f32,
                   rejoin: RejoinStats,
                   repo: RepoStats| RankOutcome {
        status,
        state: ring.latest().clone(),
        events,
        loss_curve: curves,
        timer,
        param_hash: param_hash(&state.params),
        final_loss,
        replicas_consistent: consistent,
        mux_messages: totals.0,
        mux_words: totals.1,
        ctrl_words: totals.2,
        view: members,
        epoch: state.epoch,
        rejoin,
        repo,
    };

    loop {
        if members.len() < opts.min_ranks.max(1) {
            return Err(format!(
                "rank {my}: view shrank to {} ranks, below --min-ranks {}",
                members.len(),
                opts.min_ranks
            ));
        }
        let end = run_epoch(
            transport,
            &members,
            specs,
            opts,
            seed,
            &cur,
            &mut state,
            &mut ring,
            join_once.take(),
            &mut curves,
            &mut timer,
            &freezer,
            &mut stall_used,
            &mut totals,
            &mut final_loss,
            &mut rejoin_stats,
            repo.as_mut(),
            workload,
        )?;
        match end {
            EpochEnd::Finished { consistent } => {
                return Ok(outcome(
                    ElasticStatus::Finished,
                    consistent,
                    &state,
                    &ring,
                    events,
                    curves,
                    timer,
                    totals,
                    members,
                    final_loss,
                    rejoin_stats,
                    repo.as_ref().map(|r| r.stats()).unwrap_or_default(),
                ));
            }
            EpochEnd::Paused => {
                return Ok(outcome(
                    ElasticStatus::Paused,
                    false,
                    &state,
                    &ring,
                    events,
                    curves,
                    timer,
                    totals,
                    members,
                    final_loss,
                    rejoin_stats,
                    repo.as_ref().map(|r| r.stats()).unwrap_or_default(),
                ));
            }
            EpochEnd::Killed => {
                return Ok(outcome(
                    ElasticStatus::Killed,
                    false,
                    &state,
                    &ring,
                    events,
                    curves,
                    timer,
                    totals,
                    members,
                    final_loss,
                    rejoin_stats,
                    repo.as_ref().map(|r| r.stats()).unwrap_or_default(),
                ));
            }
            EpochEnd::Fault { suspects, pending, detect_secs } => {
                let t0 = Instant::now();
                if let Some(r) = &drv_ring {
                    // retrospective: detection ran from the last healthy
                    // step boundary until the fault surfaced (now)
                    let now = obs::now_us();
                    r.record(obs::Span {
                        phase: obs::SPAN_DETECT,
                        step: state.done as u32,
                        tag: state.epoch as u32,
                        t0_us: now.saturating_sub((detect_secs * 1e6) as u64),
                        t1_us: now,
                    });
                }
                let reshape_guard = drv_ring
                    .as_ref()
                    .map(|r| r.guard(obs::SPAN_RESHAPE, state.done as u32, state.epoch as u32));
                let agreement = agree(
                    transport,
                    my,
                    &members,
                    state.epoch,
                    &suspects,
                    state.done,
                    pending,
                    opts.lease(),
                    opts.min_ranks,
                )?;
                drop(reshape_guard);
                match agreement {
                    Agreement::Evicted(why) => {
                        crate::log_warn!("rank {my}: evicted from the view: {why}");
                        return Ok(outcome(
                            ElasticStatus::Evicted,
                            false,
                            &state,
                            &ring,
                            events,
                            curves,
                            timer,
                            totals,
                            members,
                            final_loss,
                            rejoin_stats,
                            repo.as_ref().map(|r| r.stats()).unwrap_or_default(),
                        ));
                    }
                    Agreement::View { members: next, epoch, resume_step } => {
                        let snap = ring
                            .get(resume_step)
                            .ok_or_else(|| {
                                format!(
                                    "rank {my}: rollback snapshot for step {resume_step} \
                                     missing (have up to {})",
                                    state.done
                                )
                            })?
                            .clone();
                        let lost: Vec<usize> =
                            members.iter().copied().filter(|r| !next.contains(r)).collect();
                        events.push(MembershipEvent {
                            epoch,
                            lost,
                            joined: Vec::new(),
                            detect_secs,
                            reshape_secs: t0.elapsed().as_secs_f64(),
                            resume_step,
                            world_after: next.len(),
                        });
                        cur = snap;
                        cur.view_epoch = epoch;
                        cur.step = resume_step as u64;
                        state = state_from_checkpoint(&cur, specs, opts.optimizer)?;
                        curves.retain(|&(s, _)| s < resume_step);
                        members = next;
                        // dump the rollback state so a fresh shrunken-world
                        // run can be started (and bit-compared) from it —
                        // keyed by *world* rank, so disjoint views (a
                        // solo-partitioned rank under --min-ranks 1) can
                        // never clobber each other's files
                        if let Some(prefix) = &opts.ckpt_prefix {
                            let path = format!("{prefix}_reshape_e{epoch}_rank{my}.rsck");
                            cur.save(&path).map_err(|e| format!("reshape ckpt: {e}"))?;
                        }
                    }
                }
            }
        }
    }
}

/// One membership epoch: build the stack, run steps until the job ends
/// or a fault breaks the view.
#[allow(clippy::too_many_arguments)]
fn run_epoch<T, W>(
    transport: &T,
    members: &[usize],
    specs: &[LayerSpec],
    opts: &ElasticOpts,
    seed: u64,
    cur: &Checkpoint,
    state: &mut TrainState,
    ring: &mut SnapRing,
    join: Option<JoinPlan>,
    curves: &mut Vec<(usize, f32)>,
    timer: &mut PhaseTimer,
    freezer: &Arc<Freezer>,
    stall_used: &mut [bool],
    totals: &mut (u64, u64, u64),
    final_loss: &mut f32,
    rejoin_stats: &mut RejoinStats,
    mut repo: Option<&mut CkptRepo>,
    workload: &mut W,
) -> Result<EpochEnd, String>
where
    T: Transport + Sync,
    W: Workload,
{
    let my = transport.rank();
    let k = members.len();
    let me_local = members.iter().position(|&m| m == my).expect("rank is a view member");
    let group = ProcessGroup::new(transport, members.to_vec());
    let board = Arc::new(FailBoard::new(members.to_vec()));
    let fabric = Watched::new(group, Arc::clone(&board));
    let topo = derive_topology(opts.topology, members);
    let buckets = build_epoch_buckets(specs, opts, cur);
    let n_buckets = buckets.len();
    let n_tags =
        if opts.pipeline { BUCKET_TAG_BASE as usize + n_buckets + 1 } else { 2 };
    let hb_tag = (n_tags - 1) as u32;
    // the heartbeat tag is the mux's side channel: beats stay visible
    // to the monitor's poll even while a collective blocks on the peer
    // (otherwise a step longer than the lease would read as death)
    let mux = Arc::new(TagMux::with_side_channel(fabric, n_tags as u32, hb_tag));
    let ctrl = TagChannel::new(Arc::clone(&mux), CTRL_TAG);
    let hb = TagChannel::new(Arc::clone(&mux), hb_tag);

    // per-epoch span rings, keyed by the *world* rank so the per-rank
    // trace export finds them; engine-registered rings use the group-
    // local rank and are swept up by the same export
    let epoch_ring = obs::enabled().then(|| obs::ring(my, obs::LANE_MAIN, obs::DEFAULT_CAP));
    let hb_ring = obs::enabled().then(|| obs::ring(my, obs::LANE_HEARTBEAT, obs::DEFAULT_CAP));

    let mut last_ok = Instant::now();
    let mark: Result<EpochMark, String> = thread::scope(|s| {
        let monitor = spawn_monitor(
            s,
            hb.clone(),
            Arc::clone(&board),
            Arc::clone(freezer),
            opts.heartbeat,
            opts.lease(),
            hb_ring,
        );
        let run = (|| -> Result<EpochMark, String> {
            let mut seq_engine;
            let mut pipe_engine;
            let engine: &mut dyn SyncEngine = if opts.pipeline {
                pipe_engine = Pipelined::with_topology(
                    Arc::clone(&mux),
                    topo,
                    buckets,
                    opts.inflight,
                    opts.cc,
                );
                &mut pipe_engine
            } else {
                seq_engine = Sequential::with_topology(&ctrl, topo, None, buckets, opts.cc);
                &mut seq_engine
            };

            // rejoin barrier entry: the returning rank reconciles its
            // checkpoint-stale parameters against the agreed resume
            // image before anyone steps — either a full donor stream or
            // a manifest-diffed chunk delta striped across the donors
            if let Some(j) = &join {
                let killed = join_exchange(
                    &ctrl,
                    members,
                    me_local,
                    j,
                    state,
                    opts,
                    repo.as_deref_mut(),
                    rejoin_stats,
                )?;
                // the mux is rebuilt each epoch, so the ctrl tag's
                // outbound counter right after the join IS the join
                // traffic; the full-image figure is what join_sync
                // would have moved (every layer + one tag word each)
                rejoin_stats.join_words += mux.tag_stats(CTRL_TAG).bytes() / 4;
                if my == j.rejoiner {
                    rejoin_stats.full_image_words +=
                        state.params.iter().map(|p| p.len() as u64 + 1).sum::<u64>();
                }
                if killed {
                    // the outcome path reads ring.latest(); a donor dying
                    // mid-join never reached the epoch's ring reset below
                    ring.reset(state.done, make_snapshot(state, &*engine, specs, seed));
                    monitor.stop();
                    return Ok(EpochMark::Killed);
                }
            }
            ring.reset(state.done, make_snapshot(state, &*engine, specs, seed));
            if let Some(rp) = repo.as_deref_mut() {
                rp.put_checkpoint(ring.latest()).map_err(|e| format!("ckpt repo: {e}"))?;
            }
            if let Some(j) = &join {
                if my == j.rejoiner {
                    if let Some(prefix) = &opts.ckpt_prefix {
                        let path = format!("{prefix}_join_rank{my}.rsck");
                        ring.latest().save(&path).map_err(|e| format!("join ckpt: {e}"))?;
                    }
                }
            }

            loop {
                let step = state.done;
                if step >= opts.steps {
                    let consistent =
                        match panic::catch_unwind(AssertUnwindSafe(|| {
                            replica_hashes_agree(&ctrl, &state.params)
                        })) {
                            Ok(c) => c,
                            Err(_) => {
                                monitor.stop();
                                return Ok(EpochMark::Fault);
                            }
                        };
                    monitor.stop();
                    return Ok(EpochMark::Finished { consistent });
                }
                if opts.kill.iter().any(|f| f.rank == my && f.step == step) {
                    crate::log_warn!("rank {my}: killed by fault injection at step {step}");
                    monitor.stop();
                    return Ok(EpochMark::Killed);
                }
                for (i, st) in opts.stall.iter().enumerate() {
                    if st.rank == my && st.step == step && !stall_used[i] {
                        stall_used[i] = true;
                        crate::log_warn!(
                            "rank {my}: stalling {}ms at step {step} (fault injection)",
                            st.millis
                        );
                        freezer.freeze_for(Duration::from_millis(st.millis));
                        thread::sleep(Duration::from_millis(st.millis));
                    }
                }
                if opts
                    .rejoin
                    .iter()
                    .any(|f| f.step == step && !members.contains(&f.rank))
                {
                    monitor.stop();
                    return Ok(EpochMark::Paused);
                }
                if board.has_suspects() || mux.has_oob() {
                    monitor.stop();
                    return Ok(EpochMark::Fault);
                }

                let step_guard =
                    epoch_ring.as_ref().map(|r| r.guard(obs::SPAN_STEP, step as u32, 0));
                let attempt = panic::catch_unwind(AssertUnwindSafe(|| {
                    run_step(
                        &ctrl,
                        &mut *engine,
                        specs,
                        opts,
                        &mut *state,
                        me_local,
                        k,
                        step,
                        &mut *timer,
                        &mut *curves,
                        &mut *final_loss,
                        &mut *workload,
                    )
                }));
                drop(step_guard);
                match attempt {
                    Ok(Ok(())) => {
                        state.done += 1;
                        last_ok = Instant::now();
                        ring.push(state.done, make_snapshot(state, &*engine, specs, seed));
                        if let Some(rp) = repo.as_deref_mut() {
                            rp.put_checkpoint(ring.latest())
                                .map_err(|e| format!("ckpt repo: {e}"))?;
                        }
                        if opts.ckpt_every > 0 && state.done % opts.ckpt_every == 0 {
                            if let Some(prefix) = &opts.ckpt_prefix {
                                let path = format!("{prefix}_rank{my}.rsck");
                                ring.latest()
                                    .save(&path)
                                    .map_err(|e| format!("periodic ckpt: {e}"))?;
                            }
                        }
                    }
                    Ok(Err(e)) => {
                        monitor.stop();
                        if board.has_suspects() || mux.has_oob() {
                            return Ok(EpochMark::Fault);
                        }
                        return Err(e);
                    }
                    Err(_) => {
                        monitor.stop();
                        if board.has_suspects() || mux.has_oob() {
                            return Ok(EpochMark::Fault);
                        }
                        return Err(format!(
                            "rank {my} step {step}: aborted without a recorded membership fault"
                        ));
                    }
                }
            }
        })();
        monitor.stop();
        run
    });

    // mux traffic accounting survives the epoch teardown; "control" is
    // everything that is not a bucket stream (ctrl collectives + beats)
    let (msgs, words) = mux.aggregate();
    totals.0 += msgs;
    totals.1 += words;
    totals.2 += (mux.tag_stats(CTRL_TAG).bytes() + mux.tag_stats(hb_tag).bytes()) / 4;

    match mark? {
        EpochMark::Finished { consistent } => Ok(EpochEnd::Finished { consistent }),
        EpochMark::Paused => Ok(EpochEnd::Paused),
        EpochMark::Killed => Ok(EpochEnd::Killed),
        EpochMark::Fault => {
            let detect_secs = last_ok.elapsed().as_secs_f64();
            let mut pending: Vec<VecDeque<Vec<u32>>> =
                (0..transport.world()).map(|_| VecDeque::new()).collect();
            for (local, q) in mux.drain_oob().into_iter().enumerate() {
                pending[members[local]] = q;
            }
            let suspects: Vec<usize> = board.suspects().into_iter().map(|(r, _)| r).collect();
            Ok(EpochEnd::Fault { suspects, pending, detect_secs })
        }
    }
}

/// One training step under the current view: compute → clip → dense
/// allreduce + update → compressed buckets through the engine →
/// loss logging.  Exactly the non-elastic worker's schedule, scoped to
/// the view's process group.
#[allow(clippy::too_many_arguments)]
fn run_step<C, W>(
    ctrl: &C,
    engine: &mut dyn SyncEngine,
    specs: &[LayerSpec],
    opts: &ElasticOpts,
    state: &mut TrainState,
    me_local: usize,
    k: usize,
    step: usize,
    timer: &mut PhaseTimer,
    curves: &mut Vec<(usize, f32)>,
    final_loss: &mut f32,
    workload: &mut W,
) -> Result<(), String>
where
    C: Transport,
    W: Workload,
{
    let lr = opts.lr.lr_at(step);
    let key = ShardKey { epoch: state.epoch, rank: me_local, world: k, step };
    let (loss, mut grads) =
        timer.time(phase::COMPUTE, || workload.compute(&state.params, &key))?;
    if grads.len() != specs.len() {
        return Err(format!("workload produced {} grads for {} layers", grads.len(), specs.len()));
    }

    if let Some(max_norm) = opts.clip {
        let any_compressed = specs.iter().any(|s| s.method != Method::Dense);
        let limit =
            if any_compressed { local_clip_factor(max_norm, k) } else { max_norm };
        let mut refs: Vec<&mut [f32]> = grads.iter_mut().map(|g| g.as_mut_slice()).collect();
        clip_by_global_norm(&mut refs, limit);
    }

    let scale = -lr / k as f32;
    for li in (0..specs.len()).rev() {
        if specs[li].method != Method::Dense {
            continue;
        }
        timer.time(phase::COMM_DENSE, || allreduce_mean(ctrl, &mut grads[li]));
        timer.time(phase::UPDATE, || {
            state.dense[li].apply(opts.optimizer, &mut state.params[li], &grads[li], lr)
        });
    }

    let mut unpack_secs = 0.0f64;
    {
        let params = &mut state.params;
        let mut apply = |done: BucketDone| -> Result<(), String> {
            let t0 = Instant::now();
            done.apply_to(params, scale)?;
            unpack_secs += t0.elapsed().as_secs_f64();
            Ok(())
        };
        engine.sync_step(&grads, opts.density, timer, &mut apply)?;
    }
    timer.add(phase::UNPACK, unpack_secs);

    let log_step = step % opts.log_every.max(1) == 0 || step + 1 == opts.steps;
    if log_step {
        let mut l = [loss];
        allreduce_mean(ctrl, &mut l);
        if me_local == 0 {
            curves.push((step, l[0]));
        }
    }
    *final_loss = loss;
    Ok(())
}

/// The full-image rejoin stream: the first donor sends every layer's
/// current parameter words to the rejoiner on the control channel; the
/// rejoiner overwrites its (checkpoint-stale) parameters.  Per-link
/// FIFO puts these frames ahead of the donor's first step traffic, so
/// no barrier is needed for the other members.
fn join_sync<C: Transport>(
    ctrl: &C,
    members: &[usize],
    me_local: usize,
    j: &JoinPlan,
    state: &mut TrainState,
) -> Result<(), String> {
    let donor = *j.donors.first().ok_or("join plan has no donors")?;
    let donor_local = members
        .iter()
        .position(|&r| r == donor)
        .ok_or_else(|| format!("join donor {donor} not in the view"))?;
    let join_local = members
        .iter()
        .position(|&r| r == j.rejoiner)
        .ok_or_else(|| format!("rejoiner {} not in the view", j.rejoiner))?;
    if me_local == donor_local {
        for p in &state.params {
            ctrl.send(join_local, f32s_to_words(p));
        }
    } else if me_local == join_local {
        for li in 0..state.params.len() {
            let words = ctrl
                .recv_checked(donor_local)
                .map_err(|e| format!("join sync layer {li}: {e}"))?;
            let vals = words_to_f32s(&words);
            if vals.len() != state.params[li].len() {
                return Err(format!(
                    "join sync layer {li}: got {} params, want {}",
                    vals.len(),
                    state.params[li].len()
                ));
            }
            state.params[li] = vals;
        }
    }
    Ok(())
}

// Control-channel opcodes for the delta-rejoin exchange.  The high
// bits keep them out of the way of hash words that happen to flow on
// the ctrl tag during collectives (the exchange runs before any step,
// so there is no ambiguity — the prefix is purely for debuggability).
const OP_MFT_REQ: u32 = 0xE1A0_0001;
const OP_MFT: u32 = 0xE1A0_0002;
const OP_REQ: u32 = 0xE1A0_0003;
const OP_CHUNK: u32 = 0xE1A0_0004;
const OP_DONE: u32 = 0xE1A0_0005;

/// Give up if the same chunks keep failing verification this many
/// striping rounds in a row (each round backs off exponentially).
const MAX_FETCH_ROUNDS: usize = 16;

/// A chunk the rejoiner could not satisfy locally: layer index, chunk
/// index within that layer, and the digest the manifest promises.
struct NeedChunk {
    li: usize,
    ci: usize,
    digest: u64,
}

/// Dispatch the rejoin exchange for this rank's role.  Returns true
/// when a donor was fault-injected away mid-serve and the caller must
/// exit the epoch as killed.
#[allow(clippy::too_many_arguments)]
fn join_exchange<C: Transport>(
    ctrl: &C,
    members: &[usize],
    me_local: usize,
    j: &JoinPlan,
    state: &mut TrainState,
    opts: &ElasticOpts,
    repo: Option<&mut CkptRepo>,
    stats: &mut RejoinStats,
) -> Result<bool, String> {
    if opts.rejoin_full_image {
        join_sync(ctrl, members, me_local, j, state)?;
        return Ok(false);
    }
    let my = members[me_local];
    if my == j.rejoiner {
        join_fetch_delta(ctrl, members, j, state, repo, stats)?;
        Ok(false)
    } else if j.donors.contains(&my) {
        join_donate_delta(ctrl, members, my, j, state, opts)
    } else {
        Ok(false)
    }
}

/// The rejoiner's side of the delta exchange: fetch a chunk manifest
/// from the first answering donor, diff it against the local
/// (checkpoint-stale) parameters and the content-addressed repo, then
/// fetch only the missing chunks, striped round-robin across the live
/// donors.  Every fetched chunk is digest-verified; a mismatch is
/// retried with exponential backoff, a dead donor's outstanding chunks
/// fail over to the survivors.
fn join_fetch_delta<C: Transport>(
    ctrl: &C,
    members: &[usize],
    j: &JoinPlan,
    state: &mut TrainState,
    mut repo: Option<&mut CkptRepo>,
    stats: &mut RejoinStats,
) -> Result<(), String> {
    let donors: Vec<usize> = j
        .donors
        .iter()
        .filter_map(|&d| members.iter().position(|&r| r == d))
        .collect();
    if donors.is_empty() {
        return Err("delta rejoin: no donor is a member of the view".into());
    }
    let mut alive = vec![true; donors.len()];

    // manifest from the first donor that answers, failing over in order
    let mut mft: Option<Vec<u32>> = None;
    for (di, &dl) in donors.iter().enumerate() {
        let got = ctrl
            .send_checked(dl, vec![OP_MFT_REQ])
            .ok()
            .and_then(|()| ctrl.recv_checked(dl).ok());
        match got {
            Some(m) if m.first() == Some(&OP_MFT) => {
                mft = Some(m);
                break;
            }
            _ => {
                alive[di] = false;
                stats.failovers += 1;
            }
        }
    }
    let mft = mft.ok_or("delta rejoin: every donor failed the manifest exchange")?;
    if mft.len() < 3 {
        return Err("delta rejoin: short manifest frame".into());
    }
    let chunk_elems = mft[1] as usize;
    let n_layers = mft[2] as usize;
    if chunk_elems == 0 || n_layers != state.params.len() {
        return Err(format!(
            "delta rejoin: manifest shape mismatch ({n_layers} layers at chunk width \
             {chunk_elems}, local model has {} layers)",
            state.params.len()
        ));
    }
    let mut want: Vec<Vec<u64>> = Vec::with_capacity(n_layers);
    let mut pos = 3usize;
    for li in 0..n_layers {
        let nc = *mft.get(pos).ok_or("delta rejoin: truncated manifest")? as usize;
        pos += 1;
        let expect = chunk::chunk_count(state.params[li].len(), chunk_elems);
        if nc != expect {
            return Err(format!(
                "delta rejoin: layer {li} manifest has {nc} chunks, local shape wants {expect}"
            ));
        }
        let mut digests = Vec::with_capacity(nc);
        for _ in 0..nc {
            let lo = *mft.get(pos).ok_or("delta rejoin: truncated manifest")? as u64;
            let hi = *mft.get(pos + 1).ok_or("delta rejoin: truncated manifest")? as u64;
            pos += 2;
            digests.push(lo | (hi << 32));
        }
        want.push(digests);
    }

    // diff: a chunk is satisfied by the stale parameters themselves, by
    // the local chunk repo, or — last resort — by a donor fetch
    let mut need: VecDeque<NeedChunk> = VecDeque::new();
    for (li, digests) in want.iter().enumerate() {
        for (ci, &dg) in digests.iter().enumerate() {
            let (a, b) = chunk::chunk_range(state.params[li].len(), chunk_elems, ci);
            if chunk::digest_f32(&state.params[li][a..b]) == dg {
                stats.reused_chunks += 1;
                continue;
            }
            match repo.as_deref_mut().and_then(|rp| rp.read_chunk(dg)) {
                Some(vals) if vals.len() == b - a => {
                    state.params[li][a..b].copy_from_slice(&vals);
                    stats.reused_chunks += 1;
                }
                _ => need.push_back(NeedChunk { li, ci, digest: dg }),
            }
        }
    }

    let mut round = 0usize;
    while !need.is_empty() {
        if round >= MAX_FETCH_ROUNDS {
            return Err(format!(
                "delta rejoin: {} chunks still unverified after {MAX_FETCH_ROUNDS} fetch rounds",
                need.len()
            ));
        }
        if round > 0 {
            thread::sleep(Duration::from_millis(1u64 << round.min(4)));
        }
        let live: Vec<usize> = (0..donors.len()).filter(|&d| alive[d]).collect();
        if live.is_empty() {
            return Err("delta rejoin: all donors lost before the fetch completed".into());
        }
        // stripe this round's chunks round-robin over the live donors,
        // send every request up front, then drain each donor's replies
        let batch: Vec<NeedChunk> = need.drain(..).collect();
        let mut per: Vec<Vec<NeedChunk>> = (0..live.len()).map(|_| Vec::new()).collect();
        for (i, c) in batch.into_iter().enumerate() {
            per[i % live.len()].push(c);
        }
        for (slot, chunks) in per.iter().enumerate() {
            if chunks.is_empty() {
                continue;
            }
            let mut req = Vec::with_capacity(2 + chunks.len() * 2);
            req.push(OP_REQ);
            req.push(chunks.len() as u32);
            for c in chunks {
                req.push(c.li as u32);
                req.push(c.ci as u32);
            }
            // a failed send surfaces as a failed recv below
            let _ = ctrl.send_checked(donors[live[slot]], req);
        }
        for (slot, chunks) in per.into_iter().enumerate() {
            if chunks.is_empty() {
                continue;
            }
            let di = live[slot];
            let mut lost = false;
            for c in chunks {
                if lost {
                    need.push_back(c);
                    continue;
                }
                let frame = match ctrl.recv_checked(donors[di]) {
                    Ok(f) => f,
                    Err(_) => {
                        alive[di] = false;
                        stats.failovers += 1;
                        lost = true;
                        need.push_back(c);
                        continue;
                    }
                };
                let shaped = frame.len() >= 4
                    && frame[0] == OP_CHUNK
                    && frame[1] as usize == c.li
                    && frame[2] as usize == c.ci
                    && frame[3] as usize == frame.len() - 4;
                if !shaped {
                    alive[di] = false;
                    stats.failovers += 1;
                    lost = true;
                    need.push_back(c);
                    continue;
                }
                let vals = words_to_f32s(&frame[4..]);
                let (a, b) = chunk::chunk_range(state.params[c.li].len(), chunk_elems, c.ci);
                if vals.len() == b - a && chunk::digest_f32(&vals) == c.digest {
                    state.params[c.li][a..b].copy_from_slice(&vals);
                    stats.fetched_chunks += 1;
                    stats.verified_chunks += 1;
                } else {
                    // corrupted in flight (or a lying donor): bounded
                    // retry, next round may stripe it to another donor
                    stats.retries += 1;
                    need.push_back(c);
                }
            }
        }
        round += 1;
    }

    for (di, &dl) in donors.iter().enumerate() {
        if alive[di] {
            let _ = ctrl.send_checked(dl, vec![OP_DONE]);
        }
    }
    Ok(())
}

/// A donor's side of the delta exchange: serve manifest and chunk
/// requests until the rejoiner signals OP_DONE.  Returns true when this
/// donor was fault-injected away mid-serve (`join_kill`), at which
/// point the caller exits the epoch as killed and the rejoiner fails
/// over to the surviving donors.
fn join_donate_delta<C: Transport>(
    ctrl: &C,
    members: &[usize],
    my: usize,
    j: &JoinPlan,
    state: &TrainState,
    opts: &ElasticOpts,
) -> Result<bool, String> {
    let join_local = members
        .iter()
        .position(|&r| r == j.rejoiner)
        .ok_or_else(|| format!("rejoiner {} not in the view", j.rejoiner))?;
    let chunk_elems = opts.chunk_elems.max(1);
    let kill_after_first = opts.join_kill.contains(&my);
    let mut corrupt_next = opts.join_corrupt.contains(&my);
    let mut sent = 0usize;
    loop {
        let msg = match ctrl.recv_checked(join_local) {
            Ok(m) => m,
            // the rejoiner vanished mid-exchange; the membership
            // machinery (suspects/oob) owns the fault from here
            Err(_) => return Ok(false),
        };
        match msg.first().copied() {
            Some(OP_MFT_REQ) => {
                let mut frame = vec![OP_MFT, chunk_elems as u32, state.params.len() as u32];
                for p in &state.params {
                    let digests = chunk::section_digests(p, chunk_elems);
                    frame.push(digests.len() as u32);
                    for dg in digests {
                        frame.push((dg & 0xFFFF_FFFF) as u32);
                        frame.push((dg >> 32) as u32);
                    }
                }
                if ctrl.send_checked(join_local, frame).is_err() {
                    return Ok(false);
                }
            }
            Some(OP_REQ) => {
                if msg.len() < 2 || msg.len() != 2 + msg[1] as usize * 2 {
                    return Err("delta donate: malformed chunk request frame".into());
                }
                for i in 0..msg[1] as usize {
                    if kill_after_first && sent >= 1 {
                        crate::log_warn!(
                            "rank {my}: killed by fault injection mid-rejoin \
                             (after serving {sent} chunks)"
                        );
                        return Ok(true);
                    }
                    let li = msg[2 + i * 2] as usize;
                    let ci = msg[3 + i * 2] as usize;
                    let p = state
                        .params
                        .get(li)
                        .ok_or_else(|| format!("delta donate: layer {li} out of range"))?;
                    let nc = chunk::chunk_count(p.len(), chunk_elems);
                    if ci >= nc {
                        return Err(format!(
                            "delta donate: chunk {ci} out of range for layer {li} ({nc} chunks)"
                        ));
                    }
                    let (a, b) = chunk::chunk_range(p.len(), chunk_elems, ci);
                    let mut frame = vec![OP_CHUNK, li as u32, ci as u32, (b - a) as u32];
                    frame.extend_from_slice(&f32s_to_words(&p[a..b]));
                    if corrupt_next {
                        corrupt_next = false;
                        frame[4] ^= 1;
                    }
                    if ctrl.send_checked(join_local, frame).is_err() {
                        return Ok(false);
                    }
                    sent += 1;
                }
            }
            Some(OP_DONE) => return Ok(false),
            other => return Err(format!("delta donate: unexpected ctrl frame {other:?}")),
        }
    }
}

/// Allgather the FNV parameter hashes across the view and compare.
fn replica_hashes_agree<C: Transport>(ctrl: &C, params: &[Vec<f32>]) -> bool {
    let h = param_hash(params);
    let msg = vec![(h & 0xFFFF_FFFF) as u32, (h >> 32) as u32];
    let all = allgather(ctrl, msg);
    all.iter().all(|w| w.len() == 2 && (w[0] as u64 | (w[1] as u64) << 32) == h)
}
