//! In-process fleet orchestration for elastic jobs: fabric
//! *generations* separated by rejoin barriers.
//!
//! A shrink (worker loss) is handled inside one generation — survivors
//! keep their endpoints and reshape in place.  A *rejoin* needs fresh
//! links to the returning rank, which a fixed-size fabric cannot grow;
//! the orchestrator models the paper-scale restart-with-state instead:
//! when the workers pause at the scheduled rejoin barrier (a step every
//! survivor reaches deterministically), it tears the generation down,
//! builds a new full-world `LocalFabric`, and relaunches every rank —
//! survivors carrying their paused state in memory, the rejoiner
//! restoring params/residual/momentum from its periodic `RSCK`
//! checkpoint, advanced to the barrier by the donor's parameter stream
//! ([`JoinPlan`]).  The membership epoch bumps, so the data sharder
//! re-keys and shards stay disjoint.
//!
//! Generic over the workload factory (called on each worker thread, so
//! non-`Send` runtimes like PJRT clients work), which is how
//! `coordinator::Trainer` and the artifact-free tests share this code.

use super::driver::{run_elastic_worker, ElasticOpts, ElasticStatus, JoinPlan, RankOutcome};
use super::Workload;
use crate::collectives::LocalFabric;
use crate::coordinator::checkpoint::Checkpoint;
use crate::pipeline::LayerSpec;
use std::sync::Arc;
use std::thread;
use std::time::Instant;

/// Everything a local fleet run produces.
pub struct FleetOutcome {
    /// Final (generation-merged) outcome per world rank.
    pub ranks: Vec<RankOutcome>,
    /// Fabric traffic summed over generations.
    pub bytes: u64,
    pub messages: u64,
    pub wall_secs: f64,
}

/// Merge a later generation's outcome onto a rank's history: metrics
/// accumulate, terminal state/status are the latest generation's.
fn merge(prev: Option<RankOutcome>, next: RankOutcome) -> RankOutcome {
    let Some(mut prev) = prev else { return next };
    let RankOutcome {
        status,
        state,
        events,
        loss_curve,
        timer,
        param_hash,
        final_loss,
        replicas_consistent,
        mux_messages,
        mux_words,
        ctrl_words,
        view,
        epoch,
        rejoin,
        repo,
    } = next;
    prev.timer.merge(&timer);
    prev.loss_curve.extend(loss_curve);
    prev.events.extend(events);
    prev.rejoin.absorb(&rejoin);
    prev.repo.absorb(&repo);
    RankOutcome {
        status,
        state,
        events: prev.events,
        loss_curve: prev.loss_curve,
        timer: prev.timer,
        param_hash,
        final_loss,
        replicas_consistent,
        mux_messages: prev.mux_messages + mux_messages,
        mux_words: prev.mux_words + mux_words,
        ctrl_words: prev.ctrl_words + ctrl_words,
        view,
        epoch,
        rejoin: prev.rejoin,
        repo: prev.repo,
    }
}

/// Run a full elastic job over in-process fabrics: one thread per world
/// rank per generation.  `make_init` builds a rank's starting state
/// (fresh params or a resume checkpoint); `make_workload` builds its
/// model side *on the worker thread* (runtimes need not be `Send`).
pub fn run_local_fleet<W, MI, MW>(
    world: usize,
    specs: &[LayerSpec],
    opts: &ElasticOpts,
    make_init: MI,
    make_workload: MW,
) -> Result<FleetOutcome, String>
where
    W: Workload,
    MI: Fn(usize) -> Result<Checkpoint, String> + Send + Sync,
    MW: Fn(usize) -> Result<W, String> + Send + Sync,
{
    assert!(opts.rejoin.len() <= 1, "one scheduled rejoin per run is supported");
    let start = Instant::now();
    let mut carry: Vec<Option<(Checkpoint, Option<JoinPlan>)>> =
        (0..world).map(|_| None).collect();
    let mut merged: Vec<Option<RankOutcome>> = (0..world).map(|_| None).collect();
    let mut bytes = 0u64;
    let mut messages = 0u64;

    for generation in 0..=opts.rejoin.len() {
        let mut fabric = LocalFabric::new(world);
        let stats = Arc::clone(&fabric.stats);
        let endpoints = fabric.take_all();
        let carries: Vec<Option<(Checkpoint, Option<JoinPlan>)>> =
            carry.iter_mut().map(Option::take).collect();
        let outs: Vec<Result<RankOutcome, String>> = thread::scope(|s| {
            let handles: Vec<_> = endpoints
                .into_iter()
                .zip(carries)
                .map(|(t, c)| {
                    let make_init = &make_init;
                    let make_workload = &make_workload;
                    s.spawn(move || -> Result<RankOutcome, String> {
                        let (init, join) = match c {
                            Some((ck, j)) => (ck, j),
                            None => (make_init(t.rank())?, None),
                        };
                        let mut w = make_workload(t.rank())?;
                        run_elastic_worker(&t, specs, init, join, opts, &mut w)
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| {
                    h.join().unwrap_or_else(|_| Err("worker thread panicked".into()))
                })
                .collect()
        });
        bytes += stats.bytes();
        messages += stats.message_count();

        let mut paused = false;
        for (r, o) in outs.into_iter().enumerate() {
            let o = o.map_err(|e| format!("rank {r}: {e}"))?;
            paused |= o.status == ElasticStatus::Paused;
            merged[r] = Some(merge(merged[r].take(), o));
        }
        if !paused {
            break;
        }

        // -- schedule the rejoin generation -------------------------------
        let j = opts
            .rejoin
            .first()
            .copied()
            .ok_or("fleet paused without a scheduled rejoin")?;
        if generation >= opts.rejoin.len() {
            return Err("fleet paused after its rejoin generation".into());
        }
        let rejoiner = j.rank;
        let paused_ranks: Vec<usize> = (0..world)
            .filter(|&r| merged[r].as_ref().is_some_and(|o| o.status == ElasticStatus::Paused))
            .collect();
        let donors: Vec<usize> =
            paused_ranks.iter().copied().take(opts.rejoin_donors.max(1)).collect();
        let donor = *donors.first().ok_or("no surviving rank can donate state")?;
        let donor_state = &merged[donor].as_ref().expect("donor ran").state;
        let resume_step = donor_state.step as usize;
        let epoch_next = paused_ranks
            .iter()
            .map(|&r| merged[r].as_ref().expect("ran").epoch)
            .max()
            .unwrap_or(0)
            + 1;
        let plan = JoinPlan { rejoiner, donors, resume_step, epoch: epoch_next };
        for r in 0..world {
            let o = merged[r].as_ref().expect("all ranks ran");
            let ck = if r == rejoiner {
                let prefix = opts
                    .ckpt_prefix
                    .as_ref()
                    .ok_or("a rejoin needs --ckpt so the lost rank has state to restore")?;
                let path = format!("{prefix}_rank{r}.rsck");
                Checkpoint::load(&path)
                    .map_err(|e| format!("rejoin: rank {r} checkpoint {path}: {e}"))?
            } else {
                if o.status != ElasticStatus::Paused {
                    return Err(format!(
                        "rank {r} cannot enter the rejoin generation (status {:?})",
                        o.status
                    ));
                }
                o.state.clone()
            };
            carry[r] = Some((ck, Some(plan.clone())));
        }
    }

    let ranks: Vec<RankOutcome> =
        merged.into_iter().map(|o| o.expect("every rank ran")).collect();
    Ok(FleetOutcome { ranks, bytes, messages, wall_secs: start.elapsed().as_secs_f64() })
}
