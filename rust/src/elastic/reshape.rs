//! The world-reshape agreement protocol: survivors of a membership
//! fault agree on an epoch-numbered view and a resume step, over
//! out-of-band frames on the *raw* fabric.
//!
//! ## Frames
//!
//! Every protocol frame is `[kind, epoch, attempt, step, suspects,
//! members]` followed by the reserved [`OOB_TAG`] word on the wire.
//! The trailing tag is what lets a frame land safely at a peer still
//! inside its epoch: the epoch's `TagMux` parks it per peer and aborts
//! the in-flight collective with a clean
//! [`PeerLostCause::OutOfBand`](crate::collectives::PeerLostCause)
//! error — pulling that peer into the reshape without losing the frame.
//!
//! ## Protocol
//!
//! Symmetric, two rounds per attempt, at most [`MAX_ATTEMPTS`]:
//!
//! 1. **Announce** — every survivor sends its local suspect set (and
//!    its completed-step count) to every candidate (old members minus
//!    suspects), then collects one announce per candidate.  Learning a
//!    *new* suspect — from a frame, a link error, or a collection
//!    timeout — restarts the round at a higher attempt with the merged
//!    set, so views only ever shrink.
//! 2. **Commit** — with every candidate reporting the same attempt, the
//!    view is `old members − suspects`, the resume step is the minimum
//!    reported completed-step count (bulk-synchronous steps keep ranks
//!    within one step of each other, so the rollback ring's depth of
//!    two always covers it).  Every member sends a commit carrying
//!    `(view bitmap, resume)` and waits for everyone else's; any
//!    mismatch is a hard error, a failure mid-commit restarts.
//!
//! A rank never returns from `agree` until every member of the final
//! view committed that exact view at the same attempt, and per-link
//! FIFO order makes the commit the *last* pre-epoch frame on each
//! surviving link — so the commit round doubles as the reshape barrier
//! that drains stale epoch traffic: everything before a peer's commit
//! is discarded here, everything after belongs to the new epoch.
//!
//! ## Fault model
//!
//! Fail-stop crashes and stalls exceeding the heartbeat lease
//! (converted to hard losses by the monitor's sever), detected before
//! or during the reshape.  A member dying *mid-commit* can leave
//! survivors split across adjacent epochs; [`Dispatch::AdoptEpoch`]
//! re-merges them (the lagging side joins the committed round).  A
//! falsely-suspected rank stays suspected: it observes a view
//! excluding itself and exits [`Agreement::Evicted`] whenever the
//! `--min-ranks` floor is above one; at the permissive default floor
//! of 1 a fully partitioned rank instead continues solo (loudly
//! logged) — raise the floor for split-brain-intolerant jobs.  The
//! surviving majority's trajectory stays deterministic either way.

use crate::collectives::mux::OOB_TAG;
use crate::collectives::transport::{Transport, TransportError};
use std::collections::{BTreeMap, BTreeSet, VecDeque};
use std::thread;
use std::time::{Duration, Instant};

pub const KIND_ANNOUNCE: u32 = 0x454C_0001; // "EL" + 1
pub const KIND_COMMIT: u32 = 0x454C_0002;

/// Attempt ceiling: suspect sets only grow and are bounded by the world
/// size, so convergence needs at most one restart per newly learned
/// suspect (plus slack for attempt-number adoption).
pub const MAX_ATTEMPTS: u32 = 96;

/// Polling cadence while waiting for protocol frames.
const POLL: Duration = Duration::from_micros(500);

/// What the survivors agreed.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Agreement {
    /// The new view: members (world ranks, ascending), its epoch, and
    /// the step count every member rolls back to and resumes from.
    View { members: Vec<usize>, epoch: u64, resume_step: usize },
    /// This rank is not part of the new view (suspected by the
    /// survivors, or left without a quorum).
    Evicted(String),
}

#[derive(Clone, Copy, Debug)]
struct Frame {
    kind: u32,
    epoch: u32,
    attempt: u32,
    step: u32,
    suspects: u32,
    members: u32,
}

impl Frame {
    fn encode(&self) -> Vec<u32> {
        vec![self.kind, self.epoch, self.attempt, self.step, self.suspects, self.members]
    }

    fn decode(words: &[u32]) -> Option<Frame> {
        if words.len() != 6 || (words[0] != KIND_ANNOUNCE && words[0] != KIND_COMMIT) {
            return None;
        }
        Some(Frame {
            kind: words[0],
            epoch: words[1],
            attempt: words[2],
            step: words[3],
            suspects: words[4],
            members: words[5],
        })
    }
}

/// Send one protocol frame (payload + trailing OOB tag) on the raw
/// fabric; failures are ignored — a dead receiver surfaces on the read
/// side as a timeout or link error.
fn send_frame<T: Transport>(t: &T, to: usize, frame: &Frame) {
    let mut wire = frame.encode();
    wire.push(OOB_TAG);
    let _ = t.send_checked(to, wire);
}

enum ReadErr {
    Timeout,
    Dead(TransportError),
}

/// Next protocol frame from `from`: parked out-of-band frames first
/// (handed over from the epoch's mux), then the raw stream — where
/// anything *not* carrying the OOB tag is stale epoch traffic from the
/// aborted step and is discarded.  This discard is the "drain in-flight
/// buckets" half of the reshape barrier.
fn read_frame_from<T: Transport>(
    t: &T,
    from: usize,
    pending: &mut VecDeque<Vec<u32>>,
    deadline: Instant,
) -> Result<Vec<u32>, ReadErr> {
    loop {
        if let Some(f) = pending.pop_front() {
            return Ok(f);
        }
        match t.try_recv(from) {
            Ok(Some(mut raw)) => {
                if raw.last() == Some(&OOB_TAG) {
                    raw.pop();
                    return Ok(raw);
                }
                // stale epoch traffic (tagged bucket/control words or a
                // partial collective) — drained and dropped
            }
            Ok(None) => {
                if Instant::now() > deadline {
                    return Err(ReadErr::Timeout);
                }
                thread::sleep(POLL);
            }
            Err(e) => return Err(ReadErr::Dead(e)),
        }
    }
}

enum Dispatch {
    /// Stale or irrelevant — keep reading this link.
    Ignore,
    /// New information (suspects or a higher attempt): restart the
    /// round at this attempt.
    Restart(u32),
    /// The sender is a whole reshape ahead (it committed an epoch this
    /// rank missed — e.g. a member died mid-commit and the survivors
    /// split across adjacent epochs): adopt its `(epoch, attempt)` and
    /// restart, so the partitioned rounds re-merge instead of mutually
    /// ignoring each other until both sides time out into split views.
    AdoptEpoch(u32, u32),
    /// This link's announce for the current attempt (completed steps).
    Announce(u32),
    /// This link's commit for the current attempt (resume, members).
    Commit(u32, u32),
    /// A frame names *us* as a suspect.
    Evicted,
}

fn dispatch(
    frame: &Frame,
    my: usize,
    epoch_next: u32,
    attempt: u32,
    suspects: &mut BTreeSet<usize>,
) -> Dispatch {
    if frame.epoch < epoch_next {
        // an older reshape's stragglers — superseded
        return Dispatch::Ignore;
    }
    let frame_suspects: BTreeSet<usize> = super::ranks_of(frame.suspects).into_iter().collect();
    if frame_suspects.contains(&my) {
        return Dispatch::Evicted;
    }
    if frame.epoch > epoch_next {
        // the sender committed a reshape this rank never saw (a member
        // died between its commit sends); join its round — suspects
        // merge so the missing member stays excluded
        suspects.extend(frame_suspects);
        return Dispatch::AdoptEpoch(frame.epoch, frame.attempt.max(1));
    }
    let news: Vec<usize> =
        frame_suspects.iter().copied().filter(|r| !suspects.contains(r)).collect();
    if !news.is_empty() {
        suspects.extend(news);
        return Dispatch::Restart(attempt.max(frame.attempt) + 1);
    }
    if frame.attempt > attempt {
        return Dispatch::Restart(frame.attempt);
    }
    if frame.attempt < attempt {
        return Dispatch::Ignore;
    }
    match frame.kind {
        KIND_ANNOUNCE => Dispatch::Announce(frame.step),
        _ => Dispatch::Commit(frame.step, frame.members),
    }
}

/// Run the agreement for one membership fault.  `t` is the *raw* fabric
/// endpoint (world ranks); `old_members` the failed epoch's view;
/// `initial_suspects` everything the epoch's failure board recorded;
/// `done` this rank's completed-step count; `pending` the out-of-band
/// frames the epoch's mux parked, indexed by world rank; `lease` the
/// heartbeat lease (collection deadlines scale from it).
#[allow(clippy::too_many_arguments)]
pub fn agree<T: Transport>(
    t: &T,
    my: usize,
    old_members: &[usize],
    old_epoch: u64,
    initial_suspects: &[usize],
    done: usize,
    mut pending: Vec<VecDeque<Vec<u32>>>,
    lease: Duration,
    min_ranks: usize,
) -> Result<Agreement, String> {
    assert!(old_members.len() <= super::MAX_ELASTIC_WORLD);
    assert!(old_epoch + 1 <= u32::MAX as u64, "epoch overflow");
    assert_eq!(pending.len(), t.world(), "pending frames are world-indexed");
    // may advance further via Dispatch::AdoptEpoch (joining a round a
    // mid-commit death made us miss)
    let mut epoch_next = (old_epoch + 1) as u32;
    let window = (lease * 4).max(Duration::from_secs(2));
    let mut suspects: BTreeSet<usize> =
        initial_suspects.iter().copied().filter(|&r| r != my).collect();
    let mut attempt: u32 = 1;

    'retry: for _ in 0..MAX_ATTEMPTS {
        let members: Vec<usize> =
            old_members.iter().copied().filter(|r| !suspects.contains(r)).collect();
        if members.len() < min_ranks.max(1) || !members.contains(&my) {
            return Ok(Agreement::Evicted(format!(
                "no quorum: {} candidate ranks left of {} (min {})",
                members.len(),
                old_members.len(),
                min_ranks.max(1)
            )));
        }

        // -- round 1: announce + collect ---------------------------------
        let ann = Frame {
            kind: KIND_ANNOUNCE,
            epoch: epoch_next,
            attempt,
            step: done as u32,
            suspects: super::bitmap(suspects.iter().copied()),
            members: 0,
        };
        for &p in &members {
            if p != my {
                send_frame(t, p, &ann);
            }
        }
        let mut reports: BTreeMap<usize, u32> = BTreeMap::new();
        // Commits consumed *during* the announce round (a peer that ran
        // ahead sends its single commit once; forgetting it here would
        // make the commit round below time out on a healthy rank and
        // falsely suspect it).  Scoped per attempt — a restart abandons
        // them.
        let mut committed: BTreeMap<usize, (u32, u32)> = BTreeMap::new();
        reports.insert(my, done as u32);
        let deadline = Instant::now() + window;
        // Keepalive cadence: a peer may still be draining its aborted
        // step (comm-pool threads blocked on surviving links unblock one
        // per out-of-band frame they receive), so the announce is
        // re-sent periodically until the peer answers.  Duplicates are
        // consumed before the peer's commit by per-link FIFO, so none
        // survive the barrier.
        let resend = lease.max(Duration::from_millis(20));
        for &p in &members {
            if p == my {
                continue;
            }
            loop {
                let slice = (Instant::now() + resend).min(deadline);
                match read_frame_from(t, p, &mut pending[p], slice) {
                    Ok(words) => {
                        let Some(frame) = Frame::decode(&words) else { continue };
                        match dispatch(&frame, my, epoch_next, attempt, &mut suspects) {
                            Dispatch::Ignore => continue,
                            Dispatch::Restart(a) => {
                                attempt = a;
                                continue 'retry;
                            }
                            Dispatch::AdoptEpoch(e, a) => {
                                epoch_next = e;
                                attempt = a;
                                continue 'retry;
                            }
                            Dispatch::Announce(step) => {
                                reports.insert(p, step);
                                break;
                            }
                            // per-link FIFO puts a peer's announce ahead
                            // of its commit, so a same-attempt commit
                            // here means we already consumed the
                            // announce in an abandoned round — accept
                            // its step report and remember the commit
                            // (it will not be resent)
                            Dispatch::Commit(step, bits) => {
                                reports.insert(p, step);
                                committed.insert(p, (step, bits));
                                break;
                            }
                            Dispatch::Evicted => {
                                return Ok(Agreement::Evicted(format!(
                                    "rank {p} reports this rank as lost"
                                )));
                            }
                        }
                    }
                    Err(ReadErr::Timeout) => {
                        if Instant::now() < deadline {
                            // keepalive: nudge a peer still draining its
                            // aborted step
                            send_frame(t, p, &ann);
                            continue;
                        }
                        suspects.insert(p);
                        attempt += 1;
                        continue 'retry;
                    }
                    Err(ReadErr::Dead(e)) => {
                        crate::log_warn!("rank {my}: reshape peer {p} died announcing: {e}");
                        suspects.insert(p);
                        attempt += 1;
                        continue 'retry;
                    }
                }
            }
        }

        // -- decide + round 2: commit barrier -----------------------------
        let resume = reports.values().min().copied().unwrap_or(done as u32);
        let view_bits = super::bitmap(members.iter().copied());
        let commit = Frame {
            kind: KIND_COMMIT,
            epoch: epoch_next,
            attempt,
            step: resume,
            suspects: super::bitmap(suspects.iter().copied()),
            members: view_bits,
        };
        for &p in &members {
            if p != my {
                send_frame(t, p, &commit);
            }
        }
        let deadline = Instant::now() + window;
        for &p in &members {
            if p == my {
                continue;
            }
            // a commit harvested in the announce round counts here — the
            // peer sent its one commit and is already in the new epoch
            if let Some(&(step, bits)) = committed.get(&p) {
                if step != resume || bits != view_bits {
                    return Err(format!(
                        "reshape divergence: rank {p} committed (step {step}, members \
                         {bits:#x}) vs local (step {resume}, members {view_bits:#x})"
                    ));
                }
                continue;
            }
            loop {
                match read_frame_from(t, p, &mut pending[p], deadline) {
                    Ok(words) => {
                        let Some(frame) = Frame::decode(&words) else { continue };
                        match dispatch(&frame, my, epoch_next, attempt, &mut suspects) {
                            Dispatch::Ignore | Dispatch::Announce(_) => continue,
                            Dispatch::Restart(a) => {
                                attempt = a;
                                continue 'retry;
                            }
                            Dispatch::AdoptEpoch(e, a) => {
                                epoch_next = e;
                                attempt = a;
                                continue 'retry;
                            }
                            Dispatch::Commit(step, bits) => {
                                if step != resume || bits != view_bits {
                                    return Err(format!(
                                        "reshape divergence: rank {p} committed \
                                         (step {step}, members {bits:#x}) vs local \
                                         (step {resume}, members {view_bits:#x})"
                                    ));
                                }
                                break;
                            }
                            Dispatch::Evicted => {
                                return Ok(Agreement::Evicted(format!(
                                    "rank {p} reports this rank as lost"
                                )));
                            }
                        }
                    }
                    Err(ReadErr::Timeout) => {
                        suspects.insert(p);
                        attempt += 1;
                        continue 'retry;
                    }
                    Err(ReadErr::Dead(e)) => {
                        crate::log_warn!("rank {my}: reshape peer {p} died committing: {e}");
                        suspects.insert(p);
                        attempt += 1;
                        continue 'retry;
                    }
                }
            }
        }
        if members.len() == 1 && old_members.len() > 1 {
            // the permissive floor (--min-ranks 1) lets a fully
            // partitioned rank continue solo; a falsely-suspected but
            // alive peer may be doing the same elsewhere — raise the
            // floor to forbid this
            crate::log_warn!(
                "rank {my}: continuing SOLO after losing every peer of a {}-rank view \
                 (set --min-ranks 2 to abort instead)",
                old_members.len()
            );
        }
        return Ok(Agreement::View {
            members,
            epoch: epoch_next as u64,
            resume_step: resume as usize,
        });
    }
    Err(format!("reshape did not converge within {MAX_ATTEMPTS} attempts"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::LocalFabric;

    fn no_pending(world: usize) -> Vec<VecDeque<Vec<u32>>> {
        (0..world).map(|_| VecDeque::new()).collect()
    }

    fn run_one(
        t: crate::collectives::LocalTransport,
        world: usize,
        suspects: Vec<usize>,
        done: usize,
    ) -> Result<Agreement, String> {
        let my = t.rank();
        let old: Vec<usize> = (0..world).collect();
        agree(
            &t,
            my,
            &old,
            0,
            &suspects,
            done,
            no_pending(world),
            Duration::from_millis(50),
            1,
        )
    }

    /// 4 ranks, rank 2 dead before the reshape; rank 3 is one step
    /// ahead and only learns of the loss from the others' announces
    /// (the adoption-restart path).
    #[test]
    fn survivors_agree_on_view_and_min_step() {
        let world = 4;
        let mut fabric = LocalFabric::new(world);
        let mut ts = fabric.take_all();
        let t3 = ts.pop().unwrap();
        let _dead = ts.pop().unwrap(); // rank 2: never participates
        let t1 = ts.pop().unwrap();
        let t0 = ts.pop().unwrap();
        std::thread::scope(|s| {
            let h0 = s.spawn(move || run_one(t0, world, vec![2], 6));
            let h1 = s.spawn(move || run_one(t1, world, vec![2], 6));
            // rank 3 suspects no one yet and reports one more step done
            let h3 = s.spawn(move || run_one(t3, world, vec![], 7));
            let want = Agreement::View { members: vec![0, 1, 3], epoch: 1, resume_step: 6 };
            assert_eq!(h0.join().unwrap().unwrap(), want);
            assert_eq!(h1.join().unwrap().unwrap(), want);
            assert_eq!(h3.join().unwrap().unwrap(), want);
        });
    }

    #[test]
    fn isolated_rank_gets_evicted_by_quorum_loss() {
        // a 2-rank world where the peer is gone and min_ranks = 2:
        // the survivor cannot form a quorum and reports eviction
        let mut fabric = LocalFabric::new(2);
        let t0 = fabric.take(0);
        let _dead = fabric.take(1);
        let got = agree(
            &t0,
            0,
            &[0, 1],
            0,
            &[1],
            5,
            no_pending(2),
            Duration::from_millis(20),
            2,
        )
        .unwrap();
        assert!(matches!(got, Agreement::Evicted(_)), "{got:?}");
    }

    #[test]
    fn solo_survivor_forms_a_one_rank_view() {
        let mut fabric = LocalFabric::new(2);
        let t0 = fabric.take(0);
        let _dead = fabric.take(1);
        let got = agree(
            &t0,
            0,
            &[0, 1],
            3,
            &[1],
            9,
            no_pending(2),
            Duration::from_millis(20),
            1,
        )
        .unwrap();
        assert_eq!(got, Agreement::View { members: vec![0], epoch: 4, resume_step: 9 });
    }

    #[test]
    fn timeout_on_a_silent_peer_suspects_it() {
        // rank 1 exists but never joins the reshape (a stalled peer on a
        // fabric that cannot sever): rank 0 must time out, suspect it
        // and proceed solo
        let mut fabric = LocalFabric::new(2);
        let t0 = fabric.take(0);
        let _silent = fabric.take(1); // alive, never speaks
        let got = agree(
            &t0,
            0,
            &[0, 1],
            0,
            &[],
            4,
            no_pending(2),
            Duration::from_millis(10),
            1,
        )
        .unwrap();
        assert_eq!(got, Agreement::View { members: vec![0], epoch: 1, resume_step: 4 });
    }

    #[test]
    fn frames_roundtrip() {
        let f = Frame {
            kind: KIND_COMMIT,
            epoch: 7,
            attempt: 2,
            step: 100,
            suspects: 0b100,
            members: 0b1011,
        };
        let mut wire = f.encode();
        assert_eq!(Frame::decode(&wire).unwrap().members, 0b1011);
        wire.push(OOB_TAG);
        assert!(Frame::decode(&wire).is_none(), "wire form includes the tag");
        assert!(Frame::decode(&[1, 2, 3]).is_none());
    }
}
