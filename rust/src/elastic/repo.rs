//! Content-addressed checkpoint repository (DESIGN.md
//! §Checkpoint-Repository).
//!
//! A [`CkptRepo`] stores checkpoints as fixed-size, digest-addressed
//! chunks plus per-step *manifests* mapping layer → ordered chunk
//! digests:
//!
//! ```text
//! {root}/chunks/{digest:016x}.chk      raw little-endian f32 payload
//! {root}/manifests/step{S:020}.rsmf    Manifest (RSMF v1, FNV trailer)
//! ```
//!
//! Identical content is written once and refcounted — across the
//! 2-deep snapshot ring, across steps, and across sections (an all-zero
//! residual chunk and an all-zero velocity chunk share one file).  When
//! a manifest falls out of the retention window its chunk refcounts
//! drop, and zero-ref chunks are unlinked (garbage collection).  All
//! writes are atomic (temp file → fsync → rename via
//! [`checkpoint::write_atomic`]), so a crash mid-put never corrupts the
//! store; a torn manifest temp is simply skipped and collected on the
//! next [`CkptRepo::open`].
//!
//! The delta-rejoin protocol in [`super::driver`] uses the repository
//! as the returning rank's local chunk source: any chunk of the agreed
//! resume image whose digest is already present locally is restored
//! from disk instead of fetched from a donor.

use std::collections::HashMap;
use std::path::PathBuf;

use super::chunk;
use crate::coordinator::checkpoint::{write_atomic, Checkpoint};
use crate::coordinator::metrics::RepoStats;

const MANIFEST_MAGIC: &[u8; 4] = b"RSMF";
const MANIFEST_VERSION: u32 = 1;

/// One section's chunk listing: element count + ordered chunk digests.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SectionChunks {
    pub len: u64,
    pub digests: Vec<u64>,
}

/// One layer's chunk listings, mirroring
/// [`LayerState`](crate::coordinator::checkpoint::LayerState)'s shape.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct LayerChunks {
    pub params: SectionChunks,
    pub residual: Option<(SectionChunks, SectionChunks)>,
    pub velocity: Option<SectionChunks>,
}

impl LayerChunks {
    /// Present sections in serialization order.
    pub fn sections(&self) -> Vec<&SectionChunks> {
        let mut out = vec![&self.params];
        if let Some((v, u)) = &self.residual {
            out.push(v);
            out.push(u);
        }
        if let Some(vel) = &self.velocity {
            out.push(vel);
        }
        out
    }
}

/// A checkpoint's content listing: (step, seed, epoch) identity plus
/// every layer's ordered chunk digests at a fixed chunk width.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Manifest {
    pub step: u64,
    pub seed: u64,
    pub view_epoch: u64,
    pub chunk_elems: u32,
    pub layers: Vec<LayerChunks>,
}

impl Manifest {
    /// The manifest of `ck` chunked at `chunk_elems`.
    pub fn of(ck: &Checkpoint, chunk_elems: usize) -> Manifest {
        assert!(chunk_elems > 0, "chunk_elems must be positive");
        let sec = |xs: &[f32]| SectionChunks {
            len: xs.len() as u64,
            digests: chunk::section_digests(xs, chunk_elems),
        };
        Manifest {
            step: ck.step,
            seed: ck.seed,
            view_epoch: ck.view_epoch,
            chunk_elems: chunk_elems as u32,
            layers: ck
                .layers
                .iter()
                .map(|l| LayerChunks {
                    params: sec(&l.params),
                    residual: l.residual.as_ref().map(|(v, u)| (sec(v), sec(u))),
                    velocity: l.velocity.as_ref().map(|v| sec(v)),
                })
                .collect(),
        }
    }

    /// Every chunk digest, one entry per occurrence (refcount semantics).
    pub fn digest_occurrences(&self) -> Vec<u64> {
        let mut out = Vec::new();
        for l in &self.layers {
            for s in l.sections() {
                out.extend_from_slice(&s.digests);
            }
        }
        out
    }

    /// Serialize (RSMF v1, little-endian, FNV-1a trailer).
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(MANIFEST_MAGIC);
        out.extend_from_slice(&MANIFEST_VERSION.to_le_bytes());
        out.extend_from_slice(&self.step.to_le_bytes());
        out.extend_from_slice(&self.seed.to_le_bytes());
        out.extend_from_slice(&self.view_epoch.to_le_bytes());
        out.extend_from_slice(&self.chunk_elems.to_le_bytes());
        out.extend_from_slice(&(self.layers.len() as u32).to_le_bytes());
        for l in &self.layers {
            let flags: u32 = (l.residual.is_some() as u32) | ((l.velocity.is_some() as u32) << 1);
            out.extend_from_slice(&flags.to_le_bytes());
            for s in l.sections() {
                out.extend_from_slice(&s.len.to_le_bytes());
                out.extend_from_slice(&(s.digests.len() as u32).to_le_bytes());
                for d in &s.digests {
                    out.extend_from_slice(&d.to_le_bytes());
                }
            }
        }
        let mut dg = chunk::Digest::new();
        dg.update(&out);
        out.extend_from_slice(&dg.finish().to_le_bytes());
        out
    }

    /// Parse and verify an RSMF blob.
    pub fn from_bytes(buf: &[u8]) -> Result<Manifest, String> {
        if buf.len() < 4 + 4 + 8 + 8 + 8 + 4 + 4 + 8 {
            return Err(format!("manifest too short ({} bytes)", buf.len()));
        }
        if &buf[..4] != MANIFEST_MAGIC {
            return Err("not a manifest (bad magic)".into());
        }
        let body = &buf[..buf.len() - 8];
        let mut dg = chunk::Digest::new();
        dg.update(body);
        let stored = u64::from_le_bytes(buf[buf.len() - 8..].try_into().unwrap());
        if dg.finish() != stored {
            return Err(format!(
                "manifest trailer mismatch ({:#018x} vs stored {stored:#018x})",
                dg.finish()
            ));
        }
        let mut pos = 4usize;
        let rd_u32 = |pos: &mut usize| -> Result<u32, String> {
            if body.len() < *pos + 4 {
                return Err("manifest truncated".into());
            }
            let v = u32::from_le_bytes(body[*pos..*pos + 4].try_into().unwrap());
            *pos += 4;
            Ok(v)
        };
        let rd_u64 = |pos: &mut usize| -> Result<u64, String> {
            if body.len() < *pos + 8 {
                return Err("manifest truncated".into());
            }
            let v = u64::from_le_bytes(body[*pos..*pos + 8].try_into().unwrap());
            *pos += 8;
            Ok(v)
        };
        let version = rd_u32(&mut pos)?;
        if version != MANIFEST_VERSION {
            return Err(format!("unsupported manifest version {version}"));
        }
        let step = rd_u64(&mut pos)?;
        let seed = rd_u64(&mut pos)?;
        let view_epoch = rd_u64(&mut pos)?;
        let chunk_elems = rd_u32(&mut pos)?;
        if chunk_elems == 0 {
            return Err("zero chunk width".into());
        }
        let n_layers = rd_u32(&mut pos)? as usize;
        let mut layers = Vec::with_capacity(n_layers);
        for _ in 0..n_layers {
            let flags = rd_u32(&mut pos)?;
            let mut rd_sec = |pos: &mut usize| -> Result<SectionChunks, String> {
                let len = rd_u64(pos)?;
                let k = rd_u32(pos)? as usize;
                if k != chunk::chunk_count(len as usize, chunk_elems as usize) {
                    return Err(format!(
                        "section of {len} elems lists {k} chunks at width {chunk_elems}"
                    ));
                }
                let mut digests = Vec::with_capacity(k);
                for _ in 0..k {
                    digests.push(rd_u64(pos)?);
                }
                Ok(SectionChunks { len, digests })
            };
            let params = rd_sec(&mut pos)?;
            let residual = if flags & 1 != 0 {
                Some((rd_sec(&mut pos)?, rd_sec(&mut pos)?))
            } else {
                None
            };
            let velocity = if flags & 2 != 0 { Some(rd_sec(&mut pos)?) } else { None };
            layers.push(LayerChunks { params, residual, velocity });
        }
        if pos != body.len() {
            return Err("manifest has trailing bytes".into());
        }
        Ok(Manifest { step, seed, view_epoch, chunk_elems, layers })
    }
}

/// Walk every chunk of `ck` at `chunk_elems` in manifest order.
fn for_each_chunk<F>(ck: &Checkpoint, chunk_elems: usize, mut f: F) -> Result<(), String>
where
    F: FnMut(u64, &[f32]) -> Result<(), String>,
{
    for l in &ck.layers {
        for (_, xs) in l.sections() {
            for i in 0..chunk::chunk_count(xs.len(), chunk_elems) {
                let (s, e) = chunk::chunk_range(xs.len(), chunk_elems, i);
                f(chunk::digest_f32(&xs[s..e]), &xs[s..e])?;
            }
        }
    }
    Ok(())
}

/// The on-disk store: refcounted content-addressed chunks plus a bounded
/// window of manifests, mirroring the driver's snapshot ring depth.
pub struct CkptRepo {
    root: PathBuf,
    chunk_elems: usize,
    /// How many manifests to retain (matches the snapshot-ring depth).
    keep: usize,
    /// digest → reference count over the retained manifests.
    refs: HashMap<u64, u32>,
    /// Retained manifests, oldest insertion first.
    ring: Vec<Manifest>,
    stats: RepoStats,
}

impl CkptRepo {
    /// Open (or create) a repository at `root`, rebuilding refcounts from
    /// the surviving manifests and collecting orphaned chunks and torn
    /// temp files left by a crash.
    pub fn open(
        root: impl Into<PathBuf>,
        chunk_elems: usize,
        keep: usize,
    ) -> Result<CkptRepo, String> {
        assert!(chunk_elems > 0, "chunk_elems must be positive");
        assert!(keep > 0, "must retain at least one manifest");
        let root = root.into();
        let io = |e: std::io::Error| format!("ckpt repo {}: {e}", root.display());
        std::fs::create_dir_all(root.join("chunks")).map_err(io)?;
        std::fs::create_dir_all(root.join("manifests")).map_err(io)?;

        let mut repo = CkptRepo {
            root,
            chunk_elems,
            keep,
            refs: HashMap::new(),
            ring: Vec::new(),
            stats: RepoStats::default(),
        };

        let mut found: Vec<Manifest> = Vec::new();
        let manifest_dir = repo.root.join("manifests");
        for entry in std::fs::read_dir(&manifest_dir).map_err(io)? {
            let path = entry.map_err(io)?.path();
            let parsed = std::fs::read(&path)
                .ok()
                .and_then(|b| Manifest::from_bytes(&b).ok())
                .filter(|m| m.chunk_elems as usize == repo.chunk_elems);
            match parsed {
                Some(m) => found.push(m),
                // torn temp, corrupt blob or a different chunk width:
                // not restorable state, collect it
                None => {
                    let _ = std::fs::remove_file(&path);
                }
            }
        }
        found.sort_by_key(|m| m.step);
        for m in found {
            for d in m.digest_occurrences() {
                *repo.refs.entry(d).or_insert(0) += 1;
            }
            repo.ring.push(m);
        }
        repo.enforce_keep()?;

        // orphaned chunks: on disk but unreferenced by any manifest
        for entry in std::fs::read_dir(repo.root.join("chunks")).map_err(io)? {
            let path = entry.map_err(io)?.path();
            let live = path
                .file_name()
                .and_then(|n| n.to_str())
                .and_then(|n| n.strip_suffix(".chk"))
                .and_then(|n| u64::from_str_radix(n, 16).ok())
                .is_some_and(|d| repo.refs.contains_key(&d));
            if !live {
                let _ = std::fs::remove_file(&path);
                repo.stats.chunks_collected += 1;
            }
        }
        Ok(repo)
    }

    fn chunk_path(&self, digest: u64) -> PathBuf {
        self.root.join("chunks").join(format!("{digest:016x}.chk"))
    }

    fn manifest_path(&self, step: u64) -> PathBuf {
        self.root.join("manifests").join(format!("step{step:020}.rsmf"))
    }

    /// Store a checkpoint: unseen chunks are written once, known chunks
    /// only bump their refcount, the manifest is persisted atomically and
    /// the retention window is enforced (evicting + collecting the
    /// oldest manifest beyond `keep`). Re-putting a step replaces that
    /// step's manifest (rollback after a reshape re-runs steps).
    pub fn put_checkpoint(&mut self, ck: &Checkpoint) -> Result<Manifest, String> {
        let m = Manifest::of(ck, self.chunk_elems);
        if let Some(i) = self.ring.iter().position(|r| r.step == m.step) {
            let old = self.ring.remove(i);
            self.drop_manifest(&old)?;
        }
        for_each_chunk(ck, self.chunk_elems, |dg, data| {
            match self.refs.get_mut(&dg) {
                Some(c) => {
                    *c += 1;
                    self.stats.chunks_deduped += 1;
                }
                None => {
                    let bytes: Vec<u8> = data.iter().flat_map(|x| x.to_le_bytes()).collect();
                    write_atomic(self.chunk_path(dg), &bytes)
                        .map_err(|e| format!("chunk {dg:016x}: {e}"))?;
                    self.refs.insert(dg, 1);
                    self.stats.chunks_written += 1;
                }
            }
            Ok(())
        })?;
        write_atomic(self.manifest_path(m.step), &m.to_bytes())
            .map_err(|e| format!("manifest step {}: {e}", m.step))?;
        self.stats.manifests_written += 1;
        self.ring.push(m.clone());
        self.enforce_keep()?;
        Ok(m)
    }

    fn drop_manifest(&mut self, m: &Manifest) -> Result<(), String> {
        for d in m.digest_occurrences() {
            let gone = match self.refs.get_mut(&d) {
                Some(c) => {
                    *c -= 1;
                    *c == 0
                }
                None => false,
            };
            if gone {
                self.refs.remove(&d);
                let _ = std::fs::remove_file(self.chunk_path(d));
                self.stats.chunks_collected += 1;
            }
        }
        let _ = std::fs::remove_file(self.manifest_path(m.step));
        Ok(())
    }

    fn enforce_keep(&mut self) -> Result<(), String> {
        while self.ring.len() > self.keep {
            let old = self.ring.remove(0);
            self.drop_manifest(&old)?;
        }
        Ok(())
    }

    /// Is a chunk with this digest retained?
    pub fn has_chunk(&self, digest: u64) -> bool {
        self.refs.contains_key(&digest)
    }

    /// Read a chunk back, digest-verified: `None` if it is absent *or*
    /// fails verification (a corrupt chunk is as good as missing).
    pub fn read_chunk(&self, digest: u64) -> Option<Vec<f32>> {
        let bytes = std::fs::read(self.chunk_path(digest)).ok()?;
        if bytes.len() % 4 != 0 {
            return None;
        }
        let vals: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        (chunk::digest_f32(&vals) == digest).then_some(vals)
    }

    /// The most recently stored manifest, if any.
    pub fn latest(&self) -> Option<&Manifest> {
        self.ring.last()
    }

    /// Running store statistics.
    pub fn stats(&self) -> RepoStats {
        self.stats
    }

    /// The chunk width this repository stores at.
    pub fn chunk_elems(&self) -> usize {
        self.chunk_elems
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::checkpoint::LayerState;

    fn tmp_root(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("rs-repo-{}-{tag}", std::process::id()))
    }

    fn ck(step: u64, fill: f32) -> Checkpoint {
        Checkpoint {
            step,
            seed: 7,
            view_epoch: 0,
            layers: vec![
                LayerState {
                    params: (0..20).map(|i| fill + i as f32).collect(),
                    residual: Some((vec![0.0; 20], vec![0.0; 20])),
                    velocity: None,
                },
                LayerState {
                    params: vec![fill; 5],
                    residual: None,
                    velocity: Some(vec![0.25; 5]),
                },
            ],
        }
    }

    #[test]
    fn manifest_roundtrips_and_rejects_corruption() {
        let m = Manifest::of(&ck(3, 1.0), 8);
        let bytes = m.to_bytes();
        assert_eq!(Manifest::from_bytes(&bytes).unwrap(), m);
        for i in [0usize, 5, bytes.len() / 2, bytes.len() - 1] {
            let mut bad = bytes.clone();
            bad[i] ^= 1;
            assert!(Manifest::from_bytes(&bad).is_err(), "flip at {i}");
        }
        assert!(Manifest::from_bytes(&bytes[..bytes.len() - 3]).is_err());
    }

    #[test]
    fn identical_content_is_stored_once() {
        let root = tmp_root("dedup");
        let _ = std::fs::remove_dir_all(&root);
        let mut repo = CkptRepo::open(&root, 8, 2).unwrap();
        let m1 = repo.put_checkpoint(&ck(1, 1.0)).unwrap();
        let w1 = repo.stats().chunks_written;
        assert!(w1 > 0);
        // same content at the next step: nothing new hits the disk
        let m2 = repo.put_checkpoint(&ck(2, 1.0)).unwrap();
        assert_eq!(repo.stats().chunks_written, w1, "identical step re-wrote chunks");
        assert_eq!(
            repo.stats().chunks_deduped,
            m2.digest_occurrences().len() as u64
                + (m1.digest_occurrences().len() as u64 - w1),
            "every occurrence of known content must count as deduped"
        );
        assert_eq!(repo.stats().manifests_written, 2);
        // every digest is readable and verifies
        for d in m2.digest_occurrences() {
            assert!(repo.has_chunk(d));
            assert!(repo.read_chunk(d).is_some());
        }
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn eviction_collects_unreferenced_chunks() {
        let root = tmp_root("evict");
        let _ = std::fs::remove_dir_all(&root);
        let mut repo = CkptRepo::open(&root, 8, 2).unwrap();
        let m1 = repo.put_checkpoint(&ck(1, 1.0)).unwrap();
        repo.put_checkpoint(&ck(2, 2.0)).unwrap();
        let m3 = repo.put_checkpoint(&ck(3, 3.0)).unwrap();
        assert!(repo.stats().chunks_collected > 0, "step-1-only chunks must be collected");
        // chunks unique to step 1 (params with fill 1.0) are gone…
        let unique1 = m1.layers[0].params.digests[0];
        assert!(!repo.has_chunk(unique1));
        assert!(repo.read_chunk(unique1).is_none());
        // …but shared content (all-zero residual) survives in step 3
        let shared = m3.layers[0].residual.as_ref().unwrap().0.digests[0];
        assert!(repo.read_chunk(shared).is_some());
        assert_eq!(repo.latest().map(|m| m.step), Some(3));
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn reopen_rebuilds_refcounts_and_collects_orphans() {
        let root = tmp_root("reopen");
        let _ = std::fs::remove_dir_all(&root);
        let live;
        {
            let mut repo = CkptRepo::open(&root, 8, 2).unwrap();
            let m = repo.put_checkpoint(&ck(4, 9.0)).unwrap();
            live = m.digest_occurrences();
        }
        // plant an orphan chunk and a torn manifest temp
        std::fs::write(root.join("chunks").join("00000000deadbeef.chk"), [1, 2, 3, 4])
            .unwrap();
        std::fs::write(root.join("manifests").join("step5.rsmf.tmp.1"), b"torn").unwrap();
        let repo = CkptRepo::open(&root, 8, 2).unwrap();
        for d in &live {
            assert!(repo.has_chunk(*d), "reopen must keep referenced chunk {d:016x}");
        }
        assert!(!root.join("chunks").join("00000000deadbeef.chk").exists());
        assert!(!root.join("manifests").join("step5.rsmf.tmp.1").exists());
        assert!(repo.stats().chunks_collected >= 1);
        assert_eq!(repo.latest().map(|m| m.step), Some(4));
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn read_chunk_rejects_bit_corruption() {
        let root = tmp_root("verify");
        let _ = std::fs::remove_dir_all(&root);
        let mut repo = CkptRepo::open(&root, 8, 2).unwrap();
        let m = repo.put_checkpoint(&ck(1, 5.0)).unwrap();
        let d = m.layers[0].params.digests[0];
        let path = root.join("chunks").join(format!("{d:016x}.chk"));
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[3] ^= 0x40;
        std::fs::write(&path, &bytes).unwrap();
        assert!(repo.read_chunk(d).is_none(), "corrupt chunk must fail verification");
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn same_step_re_put_replaces_the_manifest() {
        let root = tmp_root("replace");
        let _ = std::fs::remove_dir_all(&root);
        let mut repo = CkptRepo::open(&root, 8, 2).unwrap();
        repo.put_checkpoint(&ck(6, 1.0)).unwrap();
        // rollback re-runs step 6 with different content
        let m = repo.put_checkpoint(&ck(6, 2.0)).unwrap();
        assert_eq!(repo.ring.len(), 1, "same step must replace, not accumulate");
        assert_eq!(repo.latest(), Some(&m));
        // the replaced step's unique chunks were collected
        let stale = Manifest::of(&ck(6, 1.0), 8).layers[0].params.digests[0];
        assert!(!repo.has_chunk(stale));
        let _ = std::fs::remove_dir_all(&root);
    }
}
