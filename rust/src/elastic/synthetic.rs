//! Synthetic workload for the elastic subsystem's artifact-free tests
//! and benches (the same role `tests/pipeline.rs`' inline harness plays
//! for the sync engines, shared here because the chaos matrix, the
//! proptests and `e2e_throughput --elastic-smoke` all need one
//! deterministic model).
//!
//! Gradients are pure functions of `(seed, view_epoch, rank, world,
//! step, layer)` — exactly the [`ShardKey`] contract — so a reshaped
//! run and a fresh run started from the survivors' checkpoint consume
//! bit-identical "data", which is what makes the post-reshape
//! bit-identity pins meaningful without a real dataset.

use super::driver::{ShardKey, Workload};
use crate::compression::Method;
use crate::pipeline::LayerSpec;
use crate::util::rng::Pcg32;

/// Default synthetic model: a dense head plus compressed layers sized
/// so greedy fusion (cap 3000) produces multiple buckets.
pub const SIZES: &[usize] = &[2200, 700, 700, 1600, 500, 900];

/// Layer specs over [`SIZES`]: layer 0 dense, the rest compressed
/// (every second one quantized), mixing both selection paths.
pub fn specs() -> Vec<LayerSpec> {
    SIZES
        .iter()
        .enumerate()
        .map(|(i, &n)| LayerSpec {
            li: i,
            n,
            method: if i == 0 {
                Method::Dense
            } else if n >= 1500 {
                Method::SampledBinarySearch
            } else {
                Method::TrimmedTopk
            },
            quantize: i % 2 == 1,
        })
        .collect()
}

/// Rank-identical initial parameters.
pub fn init_params(seed: u64) -> Vec<Vec<f32>> {
    SIZES
        .iter()
        .enumerate()
        .map(|(i, &n)| {
            let mut rng = Pcg32::new(seed ^ 0xE1A5, i as u64);
            let mut p = vec![0f32; n];
            rng.fill_normal(&mut p, 0.5);
            p
        })
        .collect()
}

/// Deterministic synthetic model: per-(key, layer) Gaussian gradients,
/// loss = mean |param| of layer 0 (identical across ranks, so the loss
/// allreduce is exercised but trivial to reason about).
pub struct SyntheticWorkload {
    pub seed: u64,
}

/// One layer's gradient for a shard key — exposed so tests can replay
/// exactly what a rank computed.
pub fn grad(seed: u64, key: &ShardKey, li: usize, n: usize) -> Vec<f32> {
    let lo = seed
        ^ ((key.step as u64) << 24)
        ^ ((li as u64) << 16)
        ^ ((key.world as u64) << 8)
        ^ key.rank as u64;
    let hi = 0x51AB ^ key.epoch.wrapping_mul(0x9E37_79B9_7F4A_7C15);
    let mut rng = Pcg32::new(lo, hi);
    let mut g = vec![0f32; n];
    rng.fill_normal(&mut g, 1.0);
    g
}

impl Workload for SyntheticWorkload {
    fn compute(
        &mut self,
        params: &[Vec<f32>],
        key: &ShardKey,
    ) -> Result<(f32, Vec<Vec<f32>>), String> {
        let grads = SIZES
            .iter()
            .enumerate()
            .map(|(li, &n)| grad(self.seed, key, li, n))
            .collect();
        let head = &params[0];
        let loss = head.iter().map(|v| v.abs()).sum::<f32>() / head.len().max(1) as f32;
        Ok((loss, grads))
    }
}

/// [`SyntheticWorkload`] with some layers frozen (zero gradient).  The
/// Gaussian workload touches every parameter every step, which makes
/// any two checkpoints differ in every chunk; freezing layers keeps
/// their chunks bit-stable across steps, so the checkpoint-repository
/// tests can observe dedup and a delta rejoin that skips real content.
pub struct FrozenWorkload {
    pub seed: u64,
    /// Layer indices whose gradients are zeroed.
    pub frozen: Vec<usize>,
}

impl Workload for FrozenWorkload {
    fn compute(
        &mut self,
        params: &[Vec<f32>],
        key: &ShardKey,
    ) -> Result<(f32, Vec<Vec<f32>>), String> {
        let grads = SIZES
            .iter()
            .enumerate()
            .map(|(li, &n)| {
                if self.frozen.contains(&li) {
                    vec![0f32; n]
                } else {
                    grad(self.seed, key, li, n)
                }
            })
            .collect();
        let head = &params[0];
        let loss = head.iter().map(|v| v.abs()).sum::<f32>() / head.len().max(1) as f32;
        Ok((loss, grads))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grads_keyed_by_every_shard_component() {
        let k = ShardKey { epoch: 0, rank: 0, world: 4, step: 3 };
        let base = grad(7, &k, 1, 64);
        assert_eq!(grad(7, &k, 1, 64), base, "deterministic");
        assert_ne!(grad(8, &k, 1, 64), base, "seed");
        assert_ne!(grad(7, &ShardKey { rank: 1, ..k }, 1, 64), base, "rank");
        assert_ne!(grad(7, &ShardKey { world: 3, ..k }, 1, 64), base, "world");
        assert_ne!(grad(7, &ShardKey { step: 4, ..k }, 1, 64), base, "step");
        assert_ne!(grad(7, &ShardKey { epoch: 1, ..k }, 1, 64), base, "view epoch");
        assert_ne!(grad(7, &k, 2, 64), base, "layer");
    }

    #[test]
    fn specs_cover_dense_and_compressed() {
        let s = specs();
        assert_eq!(s.len(), SIZES.len());
        assert_eq!(s[0].method, Method::Dense);
        assert!(s.iter().any(|x| x.method == Method::SampledBinarySearch));
        assert!(s.iter().any(|x| x.quantize));
        let p = init_params(3);
        assert_eq!(p.iter().map(Vec::len).collect::<Vec<_>>(), SIZES.to_vec());
        assert_eq!(init_params(3)[2], p[2], "rank-identical params");
    }
}
