//! Elastic membership: keep a compressed-sync job alive through worker
//! loss and rejoin (DESIGN.md §Elastic-Membership).
//!
//! RedSync's headline numbers come from 128-GPU runs — a regime where
//! worker failure is routine — yet a lost peer historically aborted the
//! whole job.  Worse, RGC makes failure uniquely costly: every rank
//! carries *residual* state (the unsent gradient mass DGC shows is part
//! of the training trajectory), so a naive restart silently changes what
//! the job computes.  This subsystem makes membership a first-class,
//! epoch-numbered quantity:
//!
//! * **Detection** ([`heartbeat`]) — a monitor thread rides a reserved
//!   `TagMux` tag over either fabric, exchanging leases; transport-level
//!   failures surface as structured
//!   [`PeerLostCause`](crate::collectives::PeerLostCause)s (clean FIN vs
//!   mid-stream EOF vs reset vs timeout), recorded on a shared
//!   [`FailBoard`] by the [`Watched`] fabric wrapper.  Over TCP an
//!   expired lease *severs* the link, converting a silent stall into a
//!   detectable loss.
//! * **Reshape** ([`reshape`]) — on a confirmed loss, survivors drain
//!   their in-flight buckets (every step ends at the engines' apply
//!   barrier, and an aborted step is rolled back), agree on an
//!   epoch-numbered membership view over out-of-band protocol frames,
//!   roll back to the last step boundary every survivor completed, and
//!   rebuild `Topology`/`ProcessGroup`s, the `Communicator` stack and
//!   the `SyncEngine` for the shrunken world ([`driver`]).
//! * **Rejoin** ([`orchestrate`]) — a returning worker restores
//!   params/residual/momentum from its `RSCK` checkpoint, diffs its
//!   stale parameter image against the agreed resume manifest and
//!   fetches only the missing chunks — digest-verified, striped across
//!   multiple donors with transparent failover ([`repo`], [`chunk`];
//!   DESIGN.md §Checkpoint-Repository) — re-enters at a step barrier,
//!   and the data sharder re-keys by `(seed, view_epoch, rank)` so
//!   shards stay disjoint.
//! * **Durability** ([`repo`]) — with `--ckpt-repo` every snapshot is
//!   stored in a chunked, content-addressed repository: unchanged
//!   chunks are written once and refcounted across the snapshot ring
//!   and across steps, and evicted manifests garbage-collect their
//!   zero-ref chunks.
//!
//! The driver is generic over a [`driver::Workload`], so the whole
//! subsystem is exercised artifact-free (`tests/elastic.rs`,
//! `e2e_throughput --elastic-smoke`) and wired to the real trainer by
//! `coordinator::worker`.

pub mod chunk;
pub mod driver;
pub mod heartbeat;
pub mod orchestrate;
pub mod repo;
pub mod reshape;
pub mod synthetic;

pub use driver::{
    fresh_checkpoint, run_elastic_worker, ElasticOpts, ElasticStatus, JoinPlan, RankOutcome,
    ShardKey, Workload,
};
pub use orchestrate::{run_local_fleet, FleetOutcome};
pub use repo::{CkptRepo, Manifest};
pub use reshape::Agreement;

use crate::collectives::group::Topology;
use crate::collectives::transport::{lock_ok, PeerLostCause, Transport, TransportError};
use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

/// Elastic views support at most this many ranks: membership travels as
/// a u32 bitmap in the reshape protocol frames.
pub const MAX_ELASTIC_WORLD: usize = 32;

/// One injected crash: world rank `rank` dies at the start of step
/// `step` (before sending anything for it) — `--kill-rank R@S`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FaultSpec {
    pub rank: usize,
    pub step: usize,
}

impl FaultSpec {
    /// Parse `R@S`, e.g. `2@6`.
    pub fn parse(s: &str) -> Result<FaultSpec, String> {
        let (r, st) = s
            .split_once('@')
            .ok_or_else(|| format!("fault '{s}': expected RANK@STEP, e.g. 2@6"))?;
        let rank = r.trim().parse().map_err(|_| format!("fault '{s}': bad rank '{r}'"))?;
        let step = st.trim().parse().map_err(|_| format!("fault '{s}': bad step '{st}'"))?;
        Ok(FaultSpec { rank, step })
    }

    /// Parse a `;`-separated list (`,` belongs to `--set`).
    pub fn parse_list(s: &str) -> Result<Vec<FaultSpec>, String> {
        s.split(';').filter(|p| !p.trim().is_empty()).map(FaultSpec::parse).collect()
    }
}

/// One injected stall: world rank `rank` freezes for `millis` at the
/// start of step `step` — `--stall-rank R@S:MS`.  The freeze covers the
/// rank's heartbeat monitor too (a SIGSTOP-faithful stall): a stall
/// longer than the lease is indistinguishable from death and gets the
/// rank evicted; a short one is ridden out.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct StallSpec {
    pub rank: usize,
    pub step: usize,
    pub millis: u64,
}

impl StallSpec {
    /// Parse `R@S:MS`, e.g. `1@4:500`.
    pub fn parse(s: &str) -> Result<StallSpec, String> {
        let (head, ms) = s
            .split_once(':')
            .ok_or_else(|| format!("stall '{s}': expected RANK@STEP:MILLIS, e.g. 1@4:500"))?;
        let f = FaultSpec::parse(head)?;
        let millis =
            ms.trim().parse().map_err(|_| format!("stall '{s}': bad duration '{ms}'"))?;
        Ok(StallSpec { rank: f.rank, step: f.step, millis })
    }

    pub fn parse_list(s: &str) -> Result<Vec<StallSpec>, String> {
        s.split(';').filter(|p| !p.trim().is_empty()).map(StallSpec::parse).collect()
    }
}

/// Pack a set of world ranks into the protocol's u32 bitmap.
pub(crate) fn bitmap(ranks: impl IntoIterator<Item = usize>) -> u32 {
    let mut b = 0u32;
    for r in ranks {
        assert!(r < MAX_ELASTIC_WORLD, "rank {r} outside the elastic bitmap");
        b |= 1 << r;
    }
    b
}

/// Unpack a bitmap into ascending world ranks.
pub(crate) fn ranks_of(bitmap: u32) -> Vec<usize> {
    (0..MAX_ELASTIC_WORLD).filter(|&r| bitmap & (1 << r) != 0).collect()
}

/// Shared failure record of one membership epoch: the [`Watched`]
/// fabric, the heartbeat monitor and the step driver all write here;
/// the reshape protocol reads it as the local suspect set.  Keys are
/// *world* ranks (the board translates the epoch's group-local ids).
pub struct FailBoard {
    members: Vec<usize>,
    suspects: Mutex<BTreeMap<usize, PeerLostCause>>,
}

impl FailBoard {
    /// `members`: the epoch's world ranks in group order.
    pub fn new(members: Vec<usize>) -> FailBoard {
        FailBoard { members, suspects: Mutex::new(BTreeMap::new()) }
    }

    /// Record a failure observed on the epoch's group-local peer id.
    pub fn mark_local(&self, local: usize, cause: PeerLostCause) {
        self.mark_world(self.members[local], cause);
    }

    /// Record a failure of a world rank directly (heartbeat monitor,
    /// fault injection).  Out-of-band "failures" are not suspicions.
    pub fn mark_world(&self, world: usize, cause: PeerLostCause) {
        if cause == PeerLostCause::OutOfBand {
            return;
        }
        lock_ok(&self.suspects).entry(world).or_insert(cause);
    }

    pub fn is_suspect_local(&self, local: usize) -> bool {
        self.is_suspect_world(self.members[local])
    }

    pub fn is_suspect_world(&self, world: usize) -> bool {
        lock_ok(&self.suspects).contains_key(&world)
    }

    pub fn has_suspects(&self) -> bool {
        !lock_ok(&self.suspects).is_empty()
    }

    /// The suspect set as `(world rank, first recorded cause)`.
    pub fn suspects(&self) -> Vec<(usize, PeerLostCause)> {
        lock_ok(&self.suspects).iter().map(|(&r, &c)| (r, c)).collect()
    }
}

/// Fabric wrapper recording every link failure on the epoch's
/// [`FailBoard`] before re-raising it — so a peer death observed deep
/// inside a collective (which aborts the step by panic, per the
/// transport contract) still leaves a structured suspect for the
/// reshape protocol.  Wraps the epoch's `ProcessGroup`, so peer ids are
/// group-local and the board translates them to world ranks.
pub struct Watched<T: Transport> {
    inner: T,
    board: Arc<FailBoard>,
}

impl<T: Transport> Watched<T> {
    pub fn new(inner: T, board: Arc<FailBoard>) -> Watched<T> {
        Watched { inner, board }
    }
}

impl<T: Transport> Transport for Watched<T> {
    fn rank(&self) -> usize {
        self.inner.rank()
    }

    fn world(&self) -> usize {
        self.inner.world()
    }

    /// Panics on a dead link like every fabric `send` (a dead peer
    /// mid-collective is fatal for the step), but records the suspect
    /// first.
    fn send(&self, to: usize, msg: Vec<u32>) {
        if let Err(e) = self.inner.send_checked(to, msg) {
            self.board.mark_local(to, e.cause);
            panic!("rank {}: send to group peer {to} failed: {e}", self.inner.rank());
        }
    }

    /// One clone per receiver, through the checked send path.  Not a
    /// hot-path regression: in the elastic stack every collective runs
    /// over a `TagChannel`, whose tagging already materializes an owned
    /// message per receiver — this direct path only exists for
    /// completeness.  Byte accounting is unchanged.
    fn send_shared(&self, to: usize, msg: &Arc<Vec<u32>>) {
        self.send(to, msg.as_ref().clone());
    }

    fn send_checked(&self, to: usize, msg: Vec<u32>) -> Result<(), TransportError> {
        self.inner.send_checked(to, msg).inspect_err(|e| self.board.mark_local(to, e.cause))
    }

    fn recv_checked(&self, from: usize) -> Result<Vec<u32>, TransportError> {
        self.inner.recv_checked(from).inspect_err(|e| self.board.mark_local(from, e.cause))
    }

    fn try_recv(&self, from: usize) -> Result<Option<Vec<u32>>, TransportError> {
        self.inner.try_recv(from).inspect_err(|e| self.board.mark_local(from, e.cause))
    }

    fn sever(&self, peer: usize) {
        self.inner.sever(peer)
    }
}

/// Re-derive the physical topology for a reshaped member list,
/// deterministically from `(planned, members)` alone (identical on
/// every survivor): the planned `nodes × ranks-per-node` shape survives
/// iff the survivors still form whole nodes — contiguous
/// `ranks_per_node`-chunks of the member list that each lie inside one
/// original node.  Anything else degrades to the flat topology (the
/// hierarchical schedule needs equal-size nodes).
pub fn derive_topology(planned: Option<Topology>, members: &[usize]) -> Topology {
    let k = members.len();
    let Some(t) = planned else {
        return Topology::flat(k);
    };
    if k == t.world() && members.iter().enumerate().all(|(i, &m)| i == m) {
        return t;
    }
    let rpn = t.ranks_per_node;
    if rpn == 0 || k % rpn != 0 || k == 0 {
        return Topology::flat(k);
    }
    let whole_nodes = members
        .chunks(rpn)
        .all(|chunk| chunk.iter().all(|&m| t.node_of(m) == t.node_of(chunk[0])));
    if whole_nodes {
        Topology::new(k / rpn, rpn)
    } else {
        Topology::flat(k)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::LocalFabric;

    #[test]
    fn fault_specs_parse() {
        assert_eq!(FaultSpec::parse("2@6").unwrap(), FaultSpec { rank: 2, step: 6 });
        assert_eq!(
            FaultSpec::parse_list("2@6; 3@8").unwrap(),
            vec![FaultSpec { rank: 2, step: 6 }, FaultSpec { rank: 3, step: 8 }]
        );
        assert!(FaultSpec::parse("2-6").is_err());
        assert_eq!(
            StallSpec::parse("1@4:500").unwrap(),
            StallSpec { rank: 1, step: 4, millis: 500 }
        );
        assert!(StallSpec::parse("1@4").is_err());
        assert!(FaultSpec::parse_list("").unwrap().is_empty());
    }

    #[test]
    fn bitmaps_roundtrip() {
        let ranks = vec![0usize, 2, 31];
        assert_eq!(ranks_of(bitmap(ranks.clone())), ranks);
        assert_eq!(bitmap(std::iter::empty::<usize>()), 0);
        assert!(ranks_of(0).is_empty());
    }

    #[test]
    fn board_translates_and_keeps_first_cause() {
        let board = FailBoard::new(vec![0, 1, 3]);
        board.mark_local(2, PeerLostCause::CleanFin); // group-local 2 = world 3
        board.mark_world(3, PeerLostCause::Reset); // later verdicts don't overwrite
        board.mark_world(7, PeerLostCause::OutOfBand); // not a suspicion
        assert!(board.is_suspect_world(3));
        assert!(board.is_suspect_local(2));
        assert!(!board.is_suspect_world(7));
        assert_eq!(board.suspects(), vec![(3, PeerLostCause::CleanFin)]);
    }

    #[test]
    fn watched_fabric_records_failures() {
        let mut fabric = LocalFabric::new(2);
        let a = fabric.take(0);
        let b = fabric.take(1);
        let board = Arc::new(FailBoard::new(vec![0, 1]));
        let w = Watched::new(&a, Arc::clone(&board));
        b.send(0, vec![7]);
        assert_eq!(w.recv_checked(1).unwrap(), vec![7]);
        assert!(!board.has_suspects());
        drop(b);
        assert!(w.recv_checked(1).is_err());
        assert_eq!(board.suspects().len(), 1);
        assert_eq!(board.suspects()[0].0, 1);
    }

    #[test]
    fn topology_survives_whole_node_loss_only() {
        let planned = Some(Topology::new(2, 2)); // nodes {0,1} {2,3}
        // full world keeps the plan
        assert_eq!(derive_topology(planned, &[0, 1, 2, 3]), Topology::new(2, 2));
        // losing a whole node keeps 2-rank nodes
        assert_eq!(derive_topology(planned, &[2, 3]), Topology::new(1, 2));
        // losing one rank of a node degrades to flat
        assert_eq!(derive_topology(planned, &[0, 1, 3]), Topology::flat(3));
        // a chunk straddling two old nodes degrades too
        assert_eq!(derive_topology(planned, &[1, 2]), Topology::flat(2));
        // no plan: always flat
        assert_eq!(derive_topology(None, &[0, 1, 3]), Topology::flat(3));
    }
}
