//! Metrics registry: counters, gauges and log₂-bucketed histograms,
//! snapshotted per aggregation window and exposed three ways — the
//! cross-rank gather (rank 0 merges step-latency histograms into
//! cluster p50/p99 + per-rank skew for `TrainReport`), the Prometheus
//! text exposition behind `--metrics-addr`, and a JSONL flush for the
//! bench harnesses.
//!
//! Histograms bucket a `u64` microsecond value by bit width (64
//! buckets), so a quantile is exact to within 2× — the right fidelity
//! for "which rank is the straggler" at zero dependencies and a
//! fixed-size wire encoding.

use crate::util::json::{self, Value};
use std::collections::BTreeMap;
use std::sync::Mutex;

pub const HIST_BUCKETS: usize = 64;

/// Log₂-bucketed histogram of microsecond values.
#[derive(Clone, Debug, PartialEq)]
pub struct Hist {
    pub count: u64,
    pub sum_us: u64,
    /// `buckets[i]` counts values of bit width `i` (0 counts zeros).
    pub buckets: Vec<u64>,
}

impl Default for Hist {
    fn default() -> Self {
        Hist { count: 0, sum_us: 0, buckets: vec![0; HIST_BUCKETS] }
    }
}

fn bit_width(v: u64) -> usize {
    (64 - v.leading_zeros() as usize).min(HIST_BUCKETS - 1)
}

impl Hist {
    pub fn observe(&mut self, us: u64) {
        self.buckets[bit_width(us)] += 1;
        self.count += 1;
        self.sum_us += us;
    }

    pub fn merge(&mut self, other: &Hist) {
        for (b, o) in self.buckets.iter_mut().zip(&other.buckets) {
            *b += o;
        }
        self.count += other.count;
        self.sum_us += other.sum_us;
    }

    /// Upper bound of the bucket containing quantile `q` (0 if empty).
    pub fn quantile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut acc = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            acc += c;
            if acc >= target {
                return if i == 0 { 0 } else { (1u64 << i) - 1 };
            }
        }
        u64::MAX
    }

    pub fn p50(&self) -> u64 {
        self.quantile(0.5)
    }

    pub fn p99(&self) -> u64 {
        self.quantile(0.99)
    }

    pub fn mean_us(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_us as f64 / self.count as f64
        }
    }

    /// Fixed-size wire form for the cross-rank gather:
    /// `[rank, count lo/hi, sum lo/hi, 64 × bucket lo/hi]`.
    pub fn encode(&self, rank: u32) -> Vec<u32> {
        let mut w = Vec::with_capacity(5 + 2 * HIST_BUCKETS);
        w.push(rank);
        w.push(self.count as u32);
        w.push((self.count >> 32) as u32);
        w.push(self.sum_us as u32);
        w.push((self.sum_us >> 32) as u32);
        for &b in &self.buckets {
            w.push(b as u32);
            w.push((b >> 32) as u32);
        }
        w
    }

    pub fn decode(w: &[u32]) -> Result<(u32, Hist), String> {
        if w.len() != 5 + 2 * HIST_BUCKETS {
            return Err(format!("hist frame has {} words, want {}", w.len(), 5 + 2 * HIST_BUCKETS));
        }
        let u64_at = |i: usize| w[i] as u64 | (w[i + 1] as u64) << 32;
        let mut h = Hist {
            count: u64_at(1),
            sum_us: u64_at(3),
            ..Default::default()
        };
        for i in 0..HIST_BUCKETS {
            h.buckets[i] = u64_at(5 + 2 * i);
        }
        Ok((w[0], h))
    }
}

// ------------------------------------------------------------ registry

#[derive(Default)]
struct Inner {
    counters: BTreeMap<String, u64>,
    gauges: BTreeMap<String, f64>,
    hists: BTreeMap<String, Hist>,
}

/// Thread-safe metric store; one per worker, shared with the scrape
/// thread via `Arc`.
#[derive(Default)]
pub struct Registry {
    inner: Mutex<Inner>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    pub fn inc(&self, name: &str, by: u64) {
        let mut g = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        if let Some(c) = g.counters.get_mut(name) {
            *c += by;
        } else {
            g.counters.insert(name.to_string(), by);
        }
    }

    pub fn gauge(&self, name: &str, v: f64) {
        let mut g = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        if let Some(slot) = g.gauges.get_mut(name) {
            *slot = v;
        } else {
            g.gauges.insert(name.to_string(), v);
        }
    }

    pub fn observe_us(&self, name: &str, us: u64) {
        let mut g = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        if let Some(h) = g.hists.get_mut(name) {
            h.observe(us);
        } else {
            let mut h = Hist::default();
            h.observe(us);
            g.hists.insert(name.to_string(), h);
        }
    }

    pub fn hist(&self, name: &str) -> Option<Hist> {
        self.inner.lock().unwrap_or_else(|e| e.into_inner()).hists.get(name).cloned()
    }

    pub fn snapshot(&self) -> Snapshot {
        let g = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        Snapshot {
            counters: g.counters.iter().map(|(k, v)| (k.clone(), *v)).collect(),
            gauges: g.gauges.iter().map(|(k, v)| (k.clone(), *v)).collect(),
            hists: g.hists.iter().map(|(k, v)| (k.clone(), v.clone())).collect(),
        }
    }
}

/// Point-in-time copy of a registry.
#[derive(Clone, Debug, Default)]
pub struct Snapshot {
    pub counters: Vec<(String, u64)>,
    pub gauges: Vec<(String, f64)>,
    pub hists: Vec<(String, Hist)>,
}

fn sanitize(name: &str) -> String {
    name.chars()
        .map(|c| if c.is_ascii_alphanumeric() || c == '_' || c == ':' { c } else { '_' })
        .collect()
}

impl Snapshot {
    /// One JSON object per snapshot — the JSONL flush line.
    pub fn to_json(&self) -> Value {
        let counters =
            json::obj(self.counters.iter().map(|(k, v)| (k.as_str(), json::num(*v as f64))).collect());
        let gauges =
            json::obj(self.gauges.iter().map(|(k, v)| (k.as_str(), json::num(*v))).collect());
        let hists = json::obj(
            self.hists
                .iter()
                .map(|(k, h)| {
                    (
                        k.as_str(),
                        json::obj(vec![
                            ("count", json::num(h.count as f64)),
                            ("sum_us", json::num(h.sum_us as f64)),
                            ("p50_us", json::num(h.p50() as f64)),
                            ("p99_us", json::num(h.p99() as f64)),
                        ]),
                    )
                })
                .collect(),
        );
        json::obj(vec![("counters", counters), ("gauges", gauges), ("hists", hists)])
    }

    /// Prometheus text exposition format 0.0.4 (`--metrics-addr`).
    pub fn prometheus(&self) -> String {
        let mut out = String::new();
        for (k, v) in &self.counters {
            let n = sanitize(k);
            out.push_str(&format!("# TYPE {n} counter\n{n} {v}\n"));
        }
        for (k, v) in &self.gauges {
            let n = sanitize(k);
            out.push_str(&format!("# TYPE {n} gauge\n{n} {v}\n"));
        }
        for (k, h) in &self.hists {
            let n = sanitize(k);
            out.push_str(&format!("# TYPE {n} summary\n"));
            out.push_str(&format!("{n}{{quantile=\"0.5\"}} {}\n", h.p50()));
            out.push_str(&format!("{n}{{quantile=\"0.99\"}} {}\n", h.p99()));
            out.push_str(&format!("{n}_sum {}\n", h.sum_us));
            out.push_str(&format!("{n}_count {}\n", h.count));
        }
        out
    }
}

// ------------------------------------------------------------ aggregation

/// What rank 0 derives from the gathered per-rank step histograms.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct ClusterStats {
    pub step_p50_us: u64,
    pub step_p99_us: u64,
    /// Max/min of per-rank mean step latency: 1.0 = perfectly even,
    /// 0.0 = never measured.
    pub rank_skew: f64,
}

/// Merge per-rank step-latency histograms into cluster quantiles and
/// the straggler skew ratio.
pub fn aggregate_step_hists(hists: &[(u32, Hist)]) -> ClusterStats {
    let mut merged = Hist::default();
    let mut means: Vec<f64> = Vec::new();
    for (_, h) in hists {
        merged.merge(h);
        if h.count > 0 {
            means.push(h.mean_us());
        }
    }
    let rank_skew = match (
        means.iter().cloned().fold(f64::INFINITY, f64::min),
        means.iter().cloned().fold(0.0f64, f64::max),
    ) {
        (min, max) if min.is_finite() && min > 0.0 => max / min,
        _ if !means.is_empty() => 1.0,
        _ => 0.0,
    };
    ClusterStats { step_p50_us: merged.p50(), step_p99_us: merged.p99(), rank_skew }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hist_buckets_by_bit_width() {
        let mut h = Hist::default();
        h.observe(0); // bucket 0
        h.observe(1); // bucket 1
        h.observe(1000); // bucket 10
        assert_eq!(h.count, 3);
        assert_eq!(h.sum_us, 1001);
        assert_eq!(h.buckets[0], 1);
        assert_eq!(h.buckets[1], 1);
        assert_eq!(h.buckets[10], 1);
    }

    #[test]
    fn quantiles_walk_cumulative_counts() {
        let mut h = Hist::default();
        for _ in 0..99 {
            h.observe(100); // bucket 7, bound 127
        }
        h.observe(1_000_000); // bucket 20, bound ~1M
        assert_eq!(h.p50(), 127);
        assert!(h.p99() >= 127);
        assert!(h.quantile(1.0) >= 1_000_000 - 1);
        assert_eq!(Hist::default().p50(), 0);
    }

    #[test]
    fn hist_codec_round_trips() {
        let mut h = Hist::default();
        h.observe(5);
        h.observe(500_000);
        let w = h.encode(2);
        let (rank, back) = Hist::decode(&w).unwrap();
        assert_eq!(rank, 2);
        assert_eq!(back, h);
        assert!(Hist::decode(&w[1..]).is_err());
    }

    #[test]
    fn registry_snapshot_and_exposition() {
        let r = Registry::new();
        r.inc("mux_bytes_total", 40);
        r.inc("mux_bytes_total", 2);
        r.gauge("union density", 0.03);
        r.observe_us("step_latency_us", 900);
        let snap = r.snapshot();
        assert_eq!(snap.counters, vec![("mux_bytes_total".to_string(), 42)]);
        let text = snap.prometheus();
        assert!(text.contains("mux_bytes_total 42"), "{text}");
        assert!(text.contains("union_density 0.03"), "sanitized name: {text}");
        assert!(text.contains("step_latency_us{quantile=\"0.5\"}"), "{text}");
        assert!(text.contains("step_latency_us_count 1"), "{text}");
        let line = snap.to_json().to_json();
        assert!(line.contains("\"p99_us\""), "{line}");
    }

    #[test]
    fn aggregation_merges_and_measures_skew() {
        let mut fast = Hist::default();
        let mut slow = Hist::default();
        for _ in 0..10 {
            fast.observe(1_000);
            slow.observe(4_000);
        }
        let stats = aggregate_step_hists(&[(0, fast.clone()), (1, slow)]);
        assert!((stats.rank_skew - 4.0).abs() < 1e-9, "{stats:?}");
        assert!(stats.step_p50_us >= 1_023);
        assert!(stats.step_p99_us >= stats.step_p50_us);
        // single rank: skew pins to 1.0; empty: 0.0
        assert_eq!(aggregate_step_hists(&[(0, fast)]).rank_skew, 1.0);
        assert_eq!(aggregate_step_hists(&[]).rank_skew, 0.0);
    }
}
