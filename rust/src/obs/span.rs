//! Span tracer: per-rank, per-lane fixed-capacity ring buffers of
//! phase-interval events, recorded behind a single global atomic so the
//! disabled path is one relaxed load and zero allocation.
//!
//! A [`Span`] is `(phase, step, tag, t_start_us, t_end_us)` — `Copy`,
//! 7 words on the wire, timestamps in wall-aligned microseconds so
//! spans from different *processes* (the TCP fabric) land on one
//! timeline: every process anchors a monotonic [`Instant`] to wall
//! time once ([`now_us`]) and derives all timestamps from that anchor,
//! so within a process ordering is monotonic while across processes
//! clocks agree to wall-clock sync error.
//!
//! Rings are preallocated at creation ([`SpanRing::new`]) and overwrite
//! the oldest span when full (the `dropped` counter says how many) —
//! recording in steady state touches no allocator, which
//! `tests/alloc_steady.rs` pins.  [`ring`] additionally registers the
//! ring in a process-global registry keyed by rank, so in-process
//! multi-rank fleets (threads over `LocalFabric`) and one-process-per-
//! rank fleets (TCP) drain through the same [`drain_rank`] call.

use crate::util::timer::PhaseTimer;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::{Instant, SystemTime, UNIX_EPOCH};

// ------------------------------------------------------------ enable gate

static ENABLED: AtomicBool = AtomicBool::new(false);

/// Turn tracing on/off globally.  Enabling also anchors the time origin
/// so no later span can predate it.
pub fn set_enabled(on: bool) {
    if on {
        origin();
    }
    ENABLED.store(on, Ordering::Relaxed);
}

/// The one check every record site performs: a single relaxed load.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

// ------------------------------------------------------------ time origin

static ORIGIN: OnceLock<(Instant, u64)> = OnceLock::new();

fn origin() -> &'static (Instant, u64) {
    ORIGIN.get_or_init(|| {
        let wall = SystemTime::now().duration_since(UNIX_EPOCH).unwrap_or_default();
        (Instant::now(), wall.as_micros() as u64)
    })
}

/// Current wall-aligned microsecond timestamp (monotonic in-process).
pub fn now_us() -> u64 {
    let (anchor, base) = origin();
    base + anchor.elapsed().as_micros() as u64
}

/// Convert an already-taken [`Instant`] to the span timebase.
pub fn instant_us(at: Instant) -> u64 {
    let (anchor, base) = origin();
    base + at.saturating_duration_since(*anchor).as_micros() as u64
}

// ------------------------------------------------------------ phases/lanes

pub const SPAN_STEP: u32 = 0;
pub const SPAN_COMPUTE: u32 = 1;
pub const SPAN_MASK: u32 = 2;
pub const SPAN_SELECT: u32 = 3;
pub const SPAN_PACK: u32 = 4;
pub const SPAN_COMM_SPARSE: u32 = 5;
pub const SPAN_COMM_DENSE: u32 = 6;
pub const SPAN_UNPACK: u32 = 7;
pub const SPAN_UPDATE: u32 = 8;
pub const SPAN_EVAL: u32 = 9;
pub const SPAN_HEARTBEAT: u32 = 10;
pub const SPAN_DETECT: u32 = 11;
pub const SPAN_RESHAPE: u32 = 12;
pub const SPAN_GATHER: u32 = 13;

/// Display name for a span phase — aligned with
/// `coordinator::metrics::phase` names so the trace and the Fig-10
/// aggregation speak one vocabulary.
pub fn span_name(phase: u32) -> &'static str {
    match phase {
        SPAN_STEP => "step",
        SPAN_COMPUTE => "compute",
        SPAN_MASK => "mask",
        SPAN_SELECT => "select",
        SPAN_PACK => "pack",
        SPAN_COMM_SPARSE => "comm_sparse",
        SPAN_COMM_DENSE => "comm_dense",
        SPAN_UNPACK => "unpack",
        SPAN_UPDATE => "update",
        SPAN_EVAL => "eval",
        SPAN_HEARTBEAT => "heartbeat",
        SPAN_DETECT => "detect",
        SPAN_RESHAPE => "reshape",
        SPAN_GATHER => "gather",
        _ => "span",
    }
}

/// Lane codes (Chrome-trace `tid` per rank): the worker/compute thread,
/// the `Pipelined` comm pool lanes, and the elastic service threads.
pub const LANE_MAIN: u32 = 0;
pub const LANE_COMM_BASE: u32 = 1;
pub const LANE_HEARTBEAT: u32 = 100;
pub const LANE_DRIVER: u32 = 101;

pub fn lane_name(lane: u32) -> String {
    match lane {
        LANE_MAIN => "main".to_string(),
        LANE_HEARTBEAT => "heartbeat".to_string(),
        LANE_DRIVER => "driver".to_string(),
        l if (LANE_COMM_BASE..LANE_HEARTBEAT).contains(&l) => {
            format!("comm-{}", l - LANE_COMM_BASE)
        }
        l => format!("lane-{l}"),
    }
}

/// Default ring capacity: 8192 spans × 40 B ≈ 320 KiB per lane; long
/// runs keep the most recent window (overwrite-oldest).
pub const DEFAULT_CAP: usize = 8192;

// ------------------------------------------------------------ span + ring

/// One timed interval.  `tag` is context-dependent: bucket id for
/// engine phases, epoch for elastic phases, 0 otherwise.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Span {
    pub phase: u32,
    pub step: u32,
    pub tag: u32,
    pub t0_us: u64,
    pub t1_us: u64,
}

struct RingBuf {
    spans: Vec<Span>,
    next: usize,
    dropped: u64,
}

struct RingInner {
    lane: u32,
    buf: Mutex<RingBuf>,
}

/// A fixed-capacity, overwrite-oldest span buffer; `Clone` shares the
/// underlying ring (comm threads clone, the owner drains).
#[derive(Clone)]
pub struct SpanRing {
    inner: Arc<RingInner>,
}

impl SpanRing {
    /// Fresh unregistered ring (tests, ad-hoc use); `capacity` is the
    /// only allocation this ring will ever make.
    pub fn new(lane: u32, capacity: usize) -> SpanRing {
        SpanRing {
            inner: Arc::new(RingInner {
                lane,
                buf: Mutex::new(RingBuf {
                    spans: Vec::with_capacity(capacity),
                    next: 0,
                    dropped: 0,
                }),
            }),
        }
    }

    pub fn lane(&self) -> u32 {
        self.inner.lane
    }

    /// Record a finished span: writes into a preallocated slot, never
    /// allocates.  Full ring overwrites the oldest entry.
    pub fn record(&self, span: Span) {
        let mut b = self.inner.buf.lock().unwrap_or_else(|e| e.into_inner());
        let cap = b.spans.capacity();
        if cap == 0 {
            b.dropped += 1;
            return;
        }
        if b.spans.len() < cap {
            b.spans.push(span);
        } else {
            let i = b.next % cap;
            b.spans[i] = span;
            b.dropped += 1;
        }
        b.next = b.next.wrapping_add(1);
    }

    /// RAII guard recording `[now, drop]` as one span.
    pub fn guard(&self, phase: u32, step: u32, tag: u32) -> SpanGuard<'_> {
        SpanGuard { ring: self, phase, step, tag, t0: Instant::now() }
    }

    pub fn len(&self) -> usize {
        self.inner.buf.lock().unwrap_or_else(|e| e.into_inner()).spans.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Take every recorded span (oldest first) plus the overwrite
    /// count, resetting the ring (capacity is kept).
    pub fn drain(&self) -> (Vec<Span>, u64) {
        let mut b = self.inner.buf.lock().unwrap_or_else(|e| e.into_inner());
        let cap = b.spans.capacity();
        let dropped = b.dropped;
        let mut out = Vec::with_capacity(b.spans.len());
        if dropped > 0 && b.spans.len() == cap && cap > 0 {
            let start = b.next % cap;
            out.extend_from_slice(&b.spans[start..]);
            out.extend_from_slice(&b.spans[..start]);
        } else {
            out.extend_from_slice(&b.spans);
        }
        b.spans.clear();
        b.next = 0;
        b.dropped = 0;
        (out, dropped)
    }
}

/// Scope guard from [`SpanRing::guard`].
pub struct SpanGuard<'a> {
    ring: &'a SpanRing,
    phase: u32,
    step: u32,
    tag: u32,
    t0: Instant,
}

impl Drop for SpanGuard<'_> {
    fn drop(&mut self) {
        self.ring.record(Span {
            phase: self.phase,
            step: self.step,
            tag: self.tag,
            t0_us: instant_us(self.t0),
            t1_us: now_us(),
        });
    }
}

// ------------------------------------------------------------ registry

struct Entry {
    rank: usize,
    ring: SpanRing,
}

static REGISTRY: Mutex<Vec<Entry>> = Mutex::new(Vec::new());

/// Create a ring and register it under `rank` for [`drain_rank`].
pub fn ring(rank: usize, lane: u32, capacity: usize) -> SpanRing {
    let r = SpanRing::new(lane, capacity);
    REGISTRY.lock().unwrap_or_else(|e| e.into_inner()).push(Entry { rank, ring: r.clone() });
    r
}

/// One lane's drained spans.
#[derive(Clone, Debug)]
pub struct LaneDump {
    pub lane: u32,
    pub dropped: u64,
    pub spans: Vec<Span>,
}

/// Drain and deregister every ring recorded under `rank` (engines,
/// worker, heartbeat, driver — across elastic epochs).
pub fn drain_rank(rank: usize) -> Vec<LaneDump> {
    let mut reg = REGISTRY.lock().unwrap_or_else(|e| e.into_inner());
    let mut dumps = Vec::new();
    reg.retain(|e| {
        if e.rank != rank {
            return true;
        }
        let (spans, dropped) = e.ring.drain();
        dumps.push(LaneDump { lane: e.ring.lane(), dropped, spans });
        false
    });
    dumps
}

// ------------------------------------------------------------ wire codec

/// Encode a rank's lane dumps for the control-channel trace gather:
/// `[rank, n_lanes, { lane, dropped_lo, dropped_hi, n_spans, 7·n span
/// words }…]`.
pub fn encode_dumps(rank: u32, dumps: &[LaneDump]) -> Vec<u32> {
    let spans: usize = dumps.iter().map(|d| d.spans.len()).sum();
    let mut w = Vec::with_capacity(2 + dumps.len() * 4 + spans * 7);
    w.push(rank);
    w.push(dumps.len() as u32);
    for d in dumps {
        w.push(d.lane);
        w.push(d.dropped as u32);
        w.push((d.dropped >> 32) as u32);
        w.push(d.spans.len() as u32);
        for s in &d.spans {
            w.push(s.phase);
            w.push(s.step);
            w.push(s.tag);
            w.push(s.t0_us as u32);
            w.push((s.t0_us >> 32) as u32);
            w.push(s.t1_us as u32);
            w.push((s.t1_us >> 32) as u32);
        }
    }
    w
}

pub fn decode_dumps(w: &[u32]) -> Result<(u32, Vec<LaneDump>), String> {
    fn take(w: &[u32], pos: &mut usize) -> Result<u32, String> {
        let v = w.get(*pos).copied().ok_or("truncated span dump")?;
        *pos += 1;
        Ok(v)
    }
    fn take64(w: &[u32], pos: &mut usize) -> Result<u64, String> {
        let lo = take(w, pos)? as u64;
        let hi = take(w, pos)? as u64;
        Ok(lo | (hi << 32))
    }
    let mut pos = 0usize;
    let rank = take(w, &mut pos)?;
    let n_lanes = take(w, &mut pos)? as usize;
    let mut dumps = Vec::with_capacity(n_lanes);
    for _ in 0..n_lanes {
        let lane = take(w, &mut pos)?;
        let dropped = take64(w, &mut pos)?;
        let n = take(w, &mut pos)? as usize;
        let mut spans = Vec::with_capacity(n);
        for _ in 0..n {
            let phase = take(w, &mut pos)?;
            let step = take(w, &mut pos)?;
            let tag = take(w, &mut pos)?;
            let t0_us = take64(w, &mut pos)?;
            let t1_us = take64(w, &mut pos)?;
            spans.push(Span { phase, step, tag, t0_us, t1_us });
        }
        dumps.push(LaneDump { lane, dropped, spans });
    }
    if pos != w.len() {
        return Err(format!("span dump has {} trailing words", w.len() - pos));
    }
    Ok((rank, dumps))
}

// ------------------------------------------------------------ timing glue

/// Per-lap phase clock: one `Instant::now()` per boundary when enabled,
/// zero clock reads when disabled (the `CompressorConfig::timing` gate
/// `tests/alloc_steady.rs` and the bucket timing-gate test pin).
pub struct PhaseClock {
    mark: Option<Instant>,
}

impl PhaseClock {
    pub fn start(enabled: bool) -> PhaseClock {
        PhaseClock { mark: enabled.then(Instant::now) }
    }

    /// Seconds since the previous boundary (0.0 when disabled);
    /// re-marks.
    pub fn lap(&mut self) -> f64 {
        match self.mark {
            Some(t0) => {
                let t1 = Instant::now();
                self.mark = Some(t1);
                (t1 - t0).as_secs_f64()
            }
            None => 0.0,
        }
    }

    /// Lap that also records the interval as a span when a trace
    /// context is present — the single source both the Fig-10 totals
    /// and the timeline draw from.
    pub fn lap_span(&mut self, ctx: Option<&SpanCtx<'_>>, phase: u32) -> f64 {
        match self.mark {
            Some(t0) => {
                let t1 = Instant::now();
                self.mark = Some(t1);
                if let Some(c) = ctx {
                    c.ring.record(Span {
                        phase,
                        step: c.step,
                        tag: c.tag,
                        t0_us: instant_us(t0),
                        t1_us: instant_us(t1),
                    });
                }
                (t1 - t0).as_secs_f64()
            }
            None => 0.0,
        }
    }
}

/// Where a compressor-produce call should record its phase spans.
#[derive(Clone, Copy)]
pub struct SpanCtx<'a> {
    pub ring: &'a SpanRing,
    pub step: u32,
    pub tag: u32,
}

/// Time a closure into a [`PhaseTimer`] phase and (when `ring` is set)
/// record the same interval as a span — the unified accounting path
/// for loop-level phases (compute/dense/eval/…).
pub fn time_phase<T>(
    ring: Option<&SpanRing>,
    phase: u32,
    step: u32,
    tag: u32,
    timer: &mut PhaseTimer,
    name: &str,
    f: impl FnOnce() -> T,
) -> T {
    let t0 = Instant::now();
    let out = f();
    let dur = t0.elapsed();
    timer.add(name, dur.as_secs_f64());
    if let Some(r) = ring {
        let t0_us = instant_us(t0);
        r.record(Span { phase, step, tag, t0_us, t1_us: t0_us + dur.as_micros() as u64 });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(phase: u32, t0: u64) -> Span {
        Span { phase, step: 0, tag: 0, t0_us: t0, t1_us: t0 + 1 }
    }

    #[test]
    fn ring_overwrites_oldest_when_full() {
        let r = SpanRing::new(LANE_MAIN, 4);
        for i in 0..6 {
            r.record(span(i, i as u64));
        }
        let (spans, dropped) = r.drain();
        assert_eq!(dropped, 2);
        assert_eq!(spans.len(), 4);
        // oldest-first: 0 and 1 were overwritten by 4 and 5
        let phases: Vec<u32> = spans.iter().map(|s| s.phase).collect();
        assert_eq!(phases, vec![2, 3, 4, 5]);
        // drained ring is reusable
        r.record(span(9, 9));
        assert_eq!(r.len(), 1);
    }

    #[test]
    fn dump_codec_round_trips() {
        let dumps = vec![
            LaneDump {
                lane: LANE_MAIN,
                dropped: 3,
                spans: vec![
                    Span { phase: SPAN_STEP, step: 7, tag: 2, t0_us: 10, t1_us: 90 },
                    Span {
                        phase: SPAN_COMM_SPARSE,
                        step: 7,
                        tag: 1,
                        t0_us: u64::MAX - 5,
                        t1_us: u64::MAX,
                    },
                ],
            },
            LaneDump { lane: LANE_COMM_BASE, dropped: 0, spans: vec![] },
        ];
        let words = encode_dumps(3, &dumps);
        let (rank, back) = decode_dumps(&words).unwrap();
        assert_eq!(rank, 3);
        assert_eq!(back.len(), 2);
        assert_eq!(back[0].dropped, 3);
        assert_eq!(back[0].spans, dumps[0].spans);
        assert_eq!(back[1].lane, LANE_COMM_BASE);
        assert!(decode_dumps(&words[..words.len() - 1]).is_err());
    }

    #[test]
    fn disabled_clock_reads_zero() {
        let mut c = PhaseClock::start(false);
        assert_eq!(c.lap(), 0.0);
        assert_eq!(c.lap_span(None, SPAN_MASK), 0.0);
    }

    #[test]
    fn lap_span_records_into_ring() {
        let r = SpanRing::new(LANE_MAIN, 8);
        let ctx = SpanCtx { ring: &r, step: 4, tag: 1 };
        let mut c = PhaseClock::start(true);
        std::thread::sleep(std::time::Duration::from_millis(2));
        let secs = c.lap_span(Some(&ctx), SPAN_SELECT);
        assert!(secs > 0.0);
        let (spans, dropped) = r.drain();
        assert_eq!(dropped, 0);
        assert_eq!(spans.len(), 1);
        assert_eq!(spans[0].phase, SPAN_SELECT);
        assert_eq!(spans[0].step, 4);
        assert!(spans[0].t1_us >= spans[0].t0_us);
    }

    #[test]
    fn guard_records_enclosing_interval() {
        let r = SpanRing::new(LANE_DRIVER, 8);
        {
            let _g = r.guard(SPAN_RESHAPE, 2, 1);
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        let (spans, _) = r.drain();
        assert_eq!(spans.len(), 1);
        assert!(spans[0].t1_us > spans[0].t0_us);
    }

    #[test]
    fn registry_drains_by_rank() {
        // ranks chosen to be out of any real fleet's range so parallel
        // tests can't interleave with these entries
        let a = ring(9001, LANE_MAIN, 4);
        let b = ring(9002, LANE_MAIN, 4);
        a.record(span(1, 1));
        b.record(span(2, 2));
        let d = drain_rank(9001);
        assert_eq!(d.len(), 1);
        assert_eq!(d[0].spans.len(), 1);
        assert_eq!(d[0].spans[0].phase, 1);
        assert!(drain_rank(9001).is_empty(), "drain deregisters");
        let d2 = drain_rank(9002);
        assert_eq!(d2[0].spans[0].phase, 2);
    }

    #[test]
    fn time_phase_feeds_timer_and_ring() {
        let r = SpanRing::new(LANE_MAIN, 8);
        let mut timer = PhaseTimer::new();
        let v = time_phase(Some(&r), SPAN_COMPUTE, 1, 0, &mut timer, "compute", || 42);
        assert_eq!(v, 42);
        assert_eq!(timer.count("compute"), 1);
        assert_eq!(r.len(), 1);
        // without a ring only the timer moves
        let mut t2 = PhaseTimer::new();
        time_phase(None, SPAN_COMPUTE, 1, 0, &mut t2, "compute", || ());
        assert_eq!(t2.count("compute"), 1);
    }
}
