//! Unified observability: span tracing, metrics, cross-rank
//! aggregation and a live scrape endpoint (DESIGN.md §Observability).
//!
//! The paper's performance argument is a time decomposition — this
//! module makes it *visible* instead of inferred: [`span`] records
//! per-lane phase intervals into preallocated rings behind one atomic
//! ([`enabled`]); [`trace`] merges every rank's rings into a
//! Chrome/Perfetto timeline (`--trace-out`); [`metrics`] keeps
//! counters/gauges/log-bucketed histograms whose step-latency hist is
//! gathered to rank 0 every `--obs-every` steps for cluster p50/p99
//! and straggler skew; [`scrape`] serves the registry as Prometheus
//! text (`--metrics-addr`).  Everything is std-only and adds zero wire
//! traffic unless explicitly enabled.

pub mod calib;
pub mod metrics;
pub mod scrape;
pub mod span;
pub mod trace;

pub use calib::{
    decode_plan, detect_straggler, encode_plan, BucketAudit, CalibSummary, Calibrator,
    LinkEstimator,
};
pub use metrics::{aggregate_step_hists, ClusterStats, Hist, Registry, Snapshot};
pub use scrape::{serve, Scraper};
pub use span::{
    decode_dumps, drain_rank, enabled, encode_dumps, instant_us, lane_name, now_us, ring,
    set_enabled, span_name, time_phase, LaneDump, PhaseClock, Span, SpanCtx, SpanGuard, SpanRing,
    DEFAULT_CAP, LANE_COMM_BASE, LANE_DRIVER, LANE_HEARTBEAT, LANE_MAIN, SPAN_COMM_DENSE,
    SPAN_COMM_SPARSE, SPAN_COMPUTE, SPAN_DETECT, SPAN_EVAL, SPAN_GATHER, SPAN_HEARTBEAT,
    SPAN_MASK, SPAN_PACK, SPAN_RESHAPE, SPAN_SELECT, SPAN_STEP, SPAN_UNPACK, SPAN_UPDATE,
};
pub use trace::{
    chrome_trace, chrome_trace_with_counters, span_count, write_chrome_trace,
    write_chrome_trace_with_counters, CounterSeries, RankDump,
};
