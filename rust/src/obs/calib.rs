//! Telemetry-driven cost-model calibration + plan audit (DESIGN.md
//! §Observability).
//!
//! The `--algo auto` picker (`costmodel::pick_algo_on`) is only as good
//! as the `Machine` parameters behind it, and those are datasheet
//! numbers.  This module closes the loop: every synchronized bucket
//! reports its message size and measured collective wall time
//! ([`Calibrator::observe_bucket`]), an EWMA least-squares estimator
//! per link class ([`LinkEstimator`]) recovers the α/β the fabric is
//! *actually* delivering, a per-bucket ledger ([`BucketAudit`]) keeps
//! the predicted-vs-measured audit of every plan decision, and
//! [`Calibrator::replan`] re-runs the picker on the calibrated machine
//! every `--recalib-every` steps — switching sparse ↔ hierarchical live
//! (both deliver bit-identical gathered blobs, so the switch cannot
//! perturb training; dense buckets were demoted at plan time and are
//! never re-promoted mid-run).
//!
//! The observation model is the cost model's own structure
//! (`costmodel::comm_coeffs`): one collective of per-rank message size
//! `B` bytes costs `rounds·α + coef·B·β` on each link it rides.  Flat
//! schedules ride one link; the hierarchical schedule is split by
//! subtracting the current inter-node estimate and fitting the residual
//! on the intra-node coefficients — which is exactly how a straggling
//! worker inside a node (slowing every synchronous intra collective)
//! becomes visible as a degraded intra link.

use crate::collectives::group::Algo;
use crate::costmodel::{self, BucketCost};
use crate::obs::metrics::Hist;
use crate::simnet::{IntraLink, Machine};

/// Default EWMA decay per observation: ~50 observations of memory.
pub const DEFAULT_DECAY: f64 = 0.98;

/// Bytes are fitted in MB so the 2×2 normal matrix stays
/// well-conditioned next to round counts of order one.
const BYTES_SCALE: f64 = 1e-6;

/// Weight of the two datasheet pseudo-observations.  Large enough to
/// keep the normal matrix invertible when every observation shares one
/// `(rounds, bytes)` shape, small enough that real data dominates.
const PRIOR_WEIGHT: f64 = 1e-3;

/// Exponentially-weighted least squares for `T = rounds·α + bytes·β`
/// over one link class, with datasheet priors as pseudo-observations.
#[derive(Clone, Debug)]
pub struct LinkEstimator {
    decay: f64,
    srr: f64,
    srx: f64,
    sxx: f64,
    srt: f64,
    sxt: f64,
    samples: u64,
    prior_alpha: f64,
    prior_beta_mb: f64,
}

impl LinkEstimator {
    pub fn new(prior_alpha: f64, prior_beta: f64, decay: f64) -> LinkEstimator {
        assert!(decay > 0.0 && decay <= 1.0, "decay must be in (0, 1]");
        LinkEstimator {
            decay,
            srr: 0.0,
            srx: 0.0,
            sxx: 0.0,
            srt: 0.0,
            sxt: 0.0,
            samples: 0,
            prior_alpha,
            prior_beta_mb: prior_beta / BYTES_SCALE,
        }
    }

    /// Fold in one measured collective: `rounds` latency units and
    /// `bytes` serialized payload cost `secs` of wall time.
    pub fn observe(&mut self, rounds: f64, bytes: f64, secs: f64) {
        if rounds <= 0.0 && bytes <= 0.0 {
            return;
        }
        let x = bytes * BYTES_SCALE;
        self.srr = self.srr * self.decay + rounds * rounds;
        self.srx = self.srx * self.decay + rounds * x;
        self.sxx = self.sxx * self.decay + x * x;
        self.srt = self.srt * self.decay + rounds * secs;
        self.sxt = self.sxt * self.decay + x * secs;
        self.samples += 1;
    }

    pub fn samples(&self) -> u64 {
        self.samples
    }

    /// Current `(α seconds, β seconds/byte)` estimate; `None` until the
    /// first observation, so datasheet values survive an idle link.
    pub fn estimate(&self) -> Option<(f64, f64)> {
        if self.samples == 0 {
            return None;
        }
        let srr = self.srr + PRIOR_WEIGHT;
        let sxx = self.sxx + PRIOR_WEIGHT;
        let srt = self.srt + PRIOR_WEIGHT * self.prior_alpha;
        let sxt = self.sxt + PRIOR_WEIGHT * self.prior_beta_mb;
        let det = srr * sxx - self.srx * self.srx;
        if det <= 1e-30 {
            return None;
        }
        let alpha = (srt * sxx - sxt * self.srx) / det;
        let beta_mb = (sxt * srr - srt * self.srx) / det;
        Some((alpha.max(0.0), beta_mb.max(0.0) * BYTES_SCALE))
    }
}

/// Predicted-vs-measured audit of one engine bucket's plan decisions.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct BucketAudit {
    pub bucket: usize,
    /// Algorithm behind the most recent observation.
    pub algo: Option<Algo>,
    /// Observations folded in (one per synchronized step).
    pub steps: u64,
    /// Σ cost-model comm seconds under the live plan's machine model.
    pub predicted_secs: f64,
    /// Σ measured collective wall seconds.
    pub measured_secs: f64,
    /// Live algorithm switches applied by [`Calibrator::replan`].
    pub switches: u64,
}

impl BucketAudit {
    /// Measured / predicted; 0.0 before the first observation.
    pub fn error_ratio(&self) -> f64 {
        if self.predicted_secs > 0.0 {
            self.measured_secs / self.predicted_secs
        } else {
            0.0
        }
    }
}

/// End-of-run calibration summary carried in `TrainReport`.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct CalibSummary {
    /// Total link observations across both estimators.
    pub samples: u64,
    /// `replan` invocations.
    pub replans: u64,
    /// Live algorithm switches applied.
    pub switches: u64,
    /// Measured α of the flat-schedule link, microseconds (0 = none).
    pub alpha_us: f64,
    /// Measured bandwidth of the flat-schedule link, GB/s (0 = none).
    pub beta_gbps: f64,
    /// Σ predicted comm seconds across all bucket audits.
    pub predicted_secs: f64,
    /// Σ measured comm seconds across all bucket audits.
    pub measured_secs: f64,
}

impl CalibSummary {
    /// Measured / predicted; 0.0 before the first observation.
    pub fn error_ratio(&self) -> f64 {
        if self.predicted_secs > 0.0 {
            self.measured_secs / self.predicted_secs
        } else {
            0.0
        }
    }
}

/// The measurement-and-control loop behind `--recalib-every`: holds the
/// datasheet machine, the machine model the *live plan* was priced on,
/// one estimator per link class, and the per-bucket audit ledger.
pub struct Calibrator {
    machine: Machine,
    plan_machine: Machine,
    link: Option<IntraLink>,
    nodes: usize,
    ranks_per_node: usize,
    inter: LinkEstimator,
    intra: LinkEstimator,
    audits: Vec<BucketAudit>,
    replans: u64,
}

impl Calibrator {
    /// `link` mirrors the worker's planning call: `None` plans with
    /// [`costmodel::pick_algo`] (in-process fabric), `Some` with
    /// [`costmodel::pick_algo_on`] over that link class.
    pub fn new(
        machine: Machine,
        link: Option<IntraLink>,
        nodes: usize,
        ranks_per_node: usize,
        n_buckets: usize,
    ) -> Calibrator {
        let (ia, ib) = Calibrator::intra_params(&machine, link);
        Calibrator {
            inter: LinkEstimator::new(machine.alpha, machine.beta, DEFAULT_DECAY),
            intra: LinkEstimator::new(ia, ib, DEFAULT_DECAY),
            plan_machine: machine.clone(),
            machine,
            link,
            nodes,
            ranks_per_node,
            audits: (0..n_buckets)
                .map(|b| BucketAudit { bucket: b, ..Default::default() })
                .collect(),
            replans: 0,
        }
    }

    fn intra_params(m: &Machine, link: Option<IntraLink>) -> (f64, f64) {
        match link {
            Some(l) => m.link_params(l),
            None => (m.intra_alpha, m.intra_beta),
        }
    }

    /// Whether flat schedules ride the intra-host link — the exact
    /// condition under which `pick_algo_on` reprices dense/sparse.
    fn flat_on_intra(&self) -> bool {
        self.nodes <= 1 && self.link.is_some()
    }

    /// Fold in one synchronized bucket: message size in words (the
    /// packed blob every rank contributes) and the measured collective
    /// wall seconds.  Updates the link estimators and the audit ledger.
    pub fn observe_bucket(&mut self, bucket: usize, algo: Algo, msg_words: usize, comm_secs: f64) {
        let bytes = 4.0 * msg_words as f64;
        let cc = costmodel::comm_coeffs(algo, self.nodes, self.ranks_per_node);
        // audit: what the live plan's machine model predicted for this
        // collective (comm terms only — selection/unpack are device work)
        let (pia, pib) = Calibrator::intra_params(&self.plan_machine, self.link);
        let predicted = if algo == Algo::Hierarchical {
            cc.inter_rounds * self.plan_machine.alpha
                + cc.inter_bytes * bytes * self.plan_machine.beta
                + cc.intra_rounds * pia
                + cc.intra_bytes * bytes * pib
        } else if self.flat_on_intra() {
            cc.inter_rounds * pia + cc.inter_bytes * bytes * pib
        } else {
            cc.inter_rounds * self.plan_machine.alpha
                + cc.inter_bytes * bytes * self.plan_machine.beta
        };
        // estimator: attribute the measurement to the link(s) it rode
        if algo == Algo::Hierarchical && (cc.intra_rounds > 0.0 || cc.intra_bytes > 0.0) {
            let (ea, eb) =
                self.inter.estimate().unwrap_or((self.machine.alpha, self.machine.beta));
            let inter_share = cc.inter_rounds * ea + cc.inter_bytes * bytes * eb;
            let residual = (comm_secs - inter_share).max(0.0);
            self.intra.observe(cc.intra_rounds, cc.intra_bytes * bytes, residual);
        } else if self.flat_on_intra() {
            self.intra.observe(cc.inter_rounds, cc.inter_bytes * bytes, comm_secs);
        } else {
            self.inter.observe(cc.inter_rounds, cc.inter_bytes * bytes, comm_secs);
        }
        if let Some(a) = self.audits.get_mut(bucket) {
            a.algo = Some(algo);
            a.steps += 1;
            a.predicted_secs += predicted;
            a.measured_secs += comm_secs;
        }
    }

    /// The datasheet machine with every measured link overridden by its
    /// estimator — what [`replan`](Calibrator::replan) prices against.
    pub fn calibrated_machine(&self) -> Machine {
        let mut m = self.machine.clone();
        if let Some((a, b)) = self.inter.estimate() {
            m.alpha = a;
            m.beta = b;
        }
        if let Some((a, b)) = self.intra.estimate() {
            match self.link {
                None | Some(IntraLink::Smp) => {
                    m.intra_alpha = a;
                    m.intra_beta = b;
                }
                Some(IntraLink::Unix) => {
                    m.uds_alpha = a;
                    m.uds_beta = b;
                }
                Some(IntraLink::Loopback) => {
                    m.lo_alpha = a;
                    m.lo_beta = b;
                }
            }
        }
        m
    }

    /// Re-run the picker on the calibrated machine at bucket
    /// granularity.  Dense re-picks keep the current algorithm (a live
    /// bucket can only move within the sparse family — sparse and
    /// hierarchical deliver bit-identical gathered blobs, dense does
    /// not).  Returns the next plan and the number of switches; the
    /// calibrated machine becomes the model future audits predict with.
    pub fn replan(
        &mut self,
        costs: &[BucketCost],
        density: f64,
        current: &[Algo],
    ) -> (Vec<Algo>, u64) {
        let m = self.calibrated_machine();
        let mut next = current.to_vec();
        let mut switches = 0u64;
        for (i, cost) in costs.iter().enumerate().take(next.len()) {
            let (pick, _) = match self.link {
                Some(l) => {
                    costmodel::pick_algo_on(&m, l, self.nodes, self.ranks_per_node, cost, density)
                }
                None => costmodel::pick_algo(&m, self.nodes, self.ranks_per_node, cost, density),
            };
            if pick != Algo::Dense && pick != next[i] {
                next[i] = pick;
                switches += 1;
                if let Some(a) = self.audits.get_mut(i) {
                    a.switches += 1;
                }
            }
        }
        self.replans += 1;
        self.plan_machine = m;
        (next, switches)
    }

    pub fn audits(&self) -> &[BucketAudit] {
        &self.audits
    }

    pub fn summary(&self) -> CalibSummary {
        let flat = if self.flat_on_intra() { &self.intra } else { &self.inter };
        let (alpha, beta) = flat.estimate().unwrap_or((0.0, 0.0));
        let mut s = CalibSummary {
            samples: self.inter.samples() + self.intra.samples(),
            replans: self.replans,
            alpha_us: alpha * 1e6,
            beta_gbps: if beta > 0.0 { 1.0 / beta / 1e9 } else { 0.0 },
            ..Default::default()
        };
        for a in &self.audits {
            s.switches += a.switches;
            s.predicted_secs += a.predicted_secs;
            s.measured_secs += a.measured_secs;
        }
        s
    }
}

/// Straggler detection on the gathered per-rank step-latency
/// histograms: the slowest rank and its mean-latency ratio over the
/// fastest, when that ratio reaches `min_ratio` (e.g. 1.5).
pub fn detect_straggler(hists: &[(u32, Hist)], min_ratio: f64) -> Option<(u32, f64)> {
    let mut slow: Option<(u32, f64)> = None;
    let mut fast = f64::INFINITY;
    for (rank, h) in hists {
        if h.count == 0 {
            continue;
        }
        let mean = h.mean_us();
        let slower = match slow {
            Some((_, s)) => mean > s,
            None => true,
        };
        if slower {
            slow = Some((*rank, mean));
        }
        fast = fast.min(mean);
    }
    let (rank, slowest) = slow?;
    if fast > 0.0 && slowest / fast >= min_ratio {
        Some((rank, slowest / fast))
    } else {
        None
    }
}

// ------------------------------------------------------------ plan codec

/// Wire magic of a re-plan broadcast frame (`"RPLN"`).
pub const PLAN_MAGIC: u32 = 0x5250_4C4E;

/// `[MAGIC, step, n, code…]` — rank 0's re-planned per-bucket algorithm
/// vector, broadcast over the control tag at the recalibration barrier.
pub fn encode_plan(step: u32, algos: &[Algo]) -> Vec<u32> {
    let mut w = Vec::with_capacity(3 + algos.len());
    w.push(PLAN_MAGIC);
    w.push(step);
    w.push(algos.len() as u32);
    for a in algos {
        w.push(match a {
            Algo::Dense => 0,
            Algo::Sparse => 1,
            Algo::Hierarchical => 2,
        });
    }
    w
}

pub fn decode_plan(w: &[u32]) -> Result<(u32, Vec<Algo>), String> {
    if w.len() < 3 {
        return Err(format!("plan frame has {} words, want >= 3", w.len()));
    }
    if w[0] != PLAN_MAGIC {
        return Err(format!("bad plan magic {:#010x}", w[0]));
    }
    let n = w[2] as usize;
    if w.len() != 3 + n {
        return Err(format!("plan frame has {} words, want {}", w.len(), 3 + n));
    }
    let mut algos = Vec::with_capacity(n);
    for &c in &w[3..] {
        algos.push(match c {
            0 => Algo::Dense,
            1 => Algo::Sparse,
            2 => Algo::Hierarchical,
            _ => return Err(format!("bad algo code {c}")),
        });
    }
    Ok((w[1], algos))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn estimator_recovers_known_link() {
        let (alpha, beta) = (20e-6, 8e-10);
        let mut e = LinkEstimator::new(1e-6, 1e-11, DEFAULT_DECAY);
        assert!(e.estimate().is_none(), "no estimate before data");
        for (r, b) in [(3.0, 1e5), (3.0, 4e5), (1.0, 2e5), (2.0, 1.6e6), (3.0, 8e5)] {
            for _ in 0..10 {
                e.observe(r, b, r * alpha + b * beta);
            }
        }
        let (ea, eb) = e.estimate().unwrap();
        assert!((ea - alpha).abs() / alpha < 1e-2, "alpha {ea:e} vs {alpha:e}");
        assert!((eb - beta).abs() / beta < 1e-2, "beta {eb:e} vs {beta:e}");
        assert_eq!(e.samples(), 50);
    }

    #[test]
    fn estimator_forgets_the_old_regime() {
        let mut e = LinkEstimator::new(20e-6, 8e-10, 0.9);
        for (r, b) in [(3.0, 1e5), (1.0, 4e5)] {
            for _ in 0..25 {
                e.observe(r, b, r * 20e-6 + b * 8e-10);
            }
        }
        // the link degrades 10x; 200 decayed observations later the old
        // regime's weight is 0.9^200 ~ 7e-10
        let (alpha2, beta2) = (200e-6, 8e-9);
        for _ in 0..100 {
            for (r, b) in [(3.0, 1e5), (1.0, 4e5)] {
                e.observe(r, b, r * alpha2 + b * beta2);
            }
        }
        let (ea, eb) = e.estimate().unwrap();
        assert!((ea - alpha2).abs() / alpha2 < 0.05, "alpha {ea:e} vs {alpha2:e}");
        assert!((eb - beta2).abs() / beta2 < 0.05, "beta {eb:e} vs {beta2:e}");
    }

    #[test]
    fn estimator_survives_degenerate_shapes() {
        // every observation identical: the prior keeps the matrix
        // invertible and the fit still reproduces the observed point
        let mut e = LinkEstimator::new(10e-6, 1e-9, DEFAULT_DECAY);
        for _ in 0..40 {
            e.observe(3.0, 2e5, 1e-3);
        }
        let (ea, eb) = e.estimate().unwrap();
        let fit = 3.0 * ea + 2e5 * eb;
        assert!((fit - 1e-3).abs() / 1e-3 < 1e-3, "fit {fit:e} vs 1e-3");
    }

    #[test]
    fn calibrator_learns_flat_link_and_audits() {
        // fatnode datasheet, but the fabric actually delivers 4x worse
        // inter α/β; flat sparse observations must recover it
        let truth = {
            let mut m = Machine::fatnode();
            m.alpha *= 4.0;
            m.beta *= 4.0;
            m
        };
        let mut c = Calibrator::new(Machine::fatnode(), None, 2, 4, 2);
        let cc = costmodel::comm_coeffs(Algo::Sparse, 2, 4);
        for _ in 0..30 {
            for (bucket, words) in [(0usize, 50_000usize), (1, 200_000)] {
                let bytes = 4.0 * words as f64;
                let secs = cc.inter_rounds * truth.alpha + cc.inter_bytes * bytes * truth.beta;
                c.observe_bucket(bucket, Algo::Sparse, words, secs);
            }
        }
        let m = c.calibrated_machine();
        assert!((m.alpha - truth.alpha).abs() / truth.alpha < 0.02, "{:e}", m.alpha);
        assert!((m.beta - truth.beta).abs() / truth.beta < 0.02, "{:e}", m.beta);
        // the datasheet plan under-predicts a 4x-degraded link: the
        // audit ledger must show measured >> predicted
        let s = c.summary();
        assert_eq!(s.samples, 60);
        assert!(s.error_ratio() > 2.0, "error ratio {}", s.error_ratio());
        assert!(s.alpha_us > 0.0 && s.beta_gbps > 0.0, "{s:?}");
        // after replanning, predictions use the calibrated machine and
        // the ledger error settles to ~1
        let cost = BucketCost { m_elems: 20e6, t_select: 0.0, wire_bytes: 8.0 };
        let (_, _) = c.replan(&[cost, cost], 1e-3, &[Algo::Sparse, Algo::Sparse]);
        let before = c.summary();
        for _ in 0..30 {
            for (bucket, words) in [(0usize, 50_000usize), (1, 200_000)] {
                let bytes = 4.0 * words as f64;
                let secs = cc.inter_rounds * truth.alpha + cc.inter_bytes * bytes * truth.beta;
                c.observe_bucket(bucket, Algo::Sparse, words, secs);
            }
        }
        let after = c.summary();
        let tail_pred = after.predicted_secs - before.predicted_secs;
        let tail_meas = after.measured_secs - before.measured_secs;
        assert!(
            (tail_meas / tail_pred - 1.0).abs() < 0.05,
            "post-replan audit error {}",
            tail_meas / tail_pred
        );
    }

    #[test]
    fn ledger_error_is_one_when_the_model_is_right() {
        let m = Machine::fatnode();
        let mut c = Calibrator::new(m.clone(), None, 2, 4, 1);
        let cc = costmodel::comm_coeffs(Algo::Sparse, 2, 4);
        for _ in 0..10 {
            let bytes = 4.0 * 100_000.0;
            let secs = cc.inter_rounds * m.alpha + cc.inter_bytes * bytes * m.beta;
            c.observe_bucket(0, Algo::Sparse, 100_000, secs);
        }
        let a = &c.audits()[0];
        assert_eq!(a.steps, 10);
        assert!((a.error_ratio() - 1.0).abs() < 1e-9, "{}", a.error_ratio());
    }

    #[test]
    fn hierarchical_observations_calibrate_the_intra_link() {
        // inter link is healthy (datasheet); a straggler inside each
        // node degrades every intra collective.  Observations of the
        // hierarchical schedule must surface as a degraded intra link.
        let truth = Machine::fatnode_straggler();
        let mut c = Calibrator::new(Machine::fatnode(), None, 2, 4, 2);
        let cc = costmodel::comm_coeffs(Algo::Hierarchical, 2, 4);
        for _ in 0..40 {
            for (bucket, words) in [(0usize, 40_000usize), (1, 160_000)] {
                let bytes = 4.0 * words as f64;
                let secs = cc.inter_rounds * truth.alpha
                    + cc.inter_bytes * bytes * truth.beta
                    + cc.intra_rounds * truth.intra_alpha
                    + cc.intra_bytes * bytes * truth.intra_beta;
                c.observe_bucket(bucket, Algo::Hierarchical, words, secs);
            }
        }
        let m = c.calibrated_machine();
        assert!(
            (m.intra_alpha - truth.intra_alpha).abs() / truth.intra_alpha < 0.05,
            "intra alpha {:e} vs {:e}",
            m.intra_alpha,
            truth.intra_alpha
        );
        assert!(
            (m.intra_beta - truth.intra_beta).abs() / truth.intra_beta < 0.05,
            "intra beta {:e} vs {:e}",
            m.intra_beta,
            truth.intra_beta
        );
        // inter link was never directly observed: datasheet survives
        assert_eq!(m.alpha, Machine::fatnode().alpha);
    }

    #[test]
    fn replan_never_promotes_to_dense() {
        // a bucket so small the calibrated picker would choose dense:
        // the live plan must keep the current sparse algorithm
        let mut c = Calibrator::new(Machine::fatnode(), None, 2, 4, 1);
        c.observe_bucket(0, Algo::Sparse, 64, 1e-4);
        let tiny = BucketCost { m_elems: 1_000.0, t_select: 1.0, wire_bytes: 8.0 };
        let (next, switches) = c.replan(&[tiny], 1e-3, &[Algo::Sparse]);
        assert_eq!(next, vec![Algo::Sparse]);
        assert_eq!(switches, 0);
        assert_eq!(c.summary().replans, 1);
    }

    #[test]
    fn straggler_detector_flags_the_slow_rank() {
        let mut fast = Hist::default();
        let mut slow = Hist::default();
        for _ in 0..20 {
            fast.observe(1_000);
            slow.observe(2_500);
        }
        let hists = vec![(0u32, fast.clone()), (1, slow), (2, fast.clone())];
        let (rank, ratio) = detect_straggler(&hists, 1.5).unwrap();
        assert_eq!(rank, 1);
        assert!((ratio - 2.5).abs() < 1e-9, "{ratio}");
        // below threshold / degenerate inputs: no flag
        assert!(detect_straggler(&hists, 3.0).is_none());
        assert!(detect_straggler(&[], 1.5).is_none());
        assert!(detect_straggler(&[(0, fast)], 1.5).is_none());
    }

    #[test]
    fn plan_codec_round_trips_and_rejects() {
        let algos = vec![Algo::Sparse, Algo::Hierarchical, Algo::Dense, Algo::Sparse];
        let w = encode_plan(7, &algos);
        assert_eq!(w.len(), 3 + algos.len());
        let (step, back) = decode_plan(&w).unwrap();
        assert_eq!(step, 7);
        assert_eq!(back, algos);
        let (_, empty) = decode_plan(&encode_plan(0, &[])).unwrap();
        assert!(empty.is_empty());
        assert!(decode_plan(&w[..2]).is_err(), "truncated header");
        assert!(decode_plan(&w[..5]).is_err(), "truncated body");
        let mut bad = w.clone();
        bad[0] ^= 1;
        assert!(decode_plan(&bad).is_err(), "bad magic");
        let mut bad = w;
        bad[3] = 9;
        assert!(decode_plan(&bad).is_err(), "bad code");
    }
}
