//! Chrome trace-event exporter: merges every rank's span rings into one
//! Perfetto-loadable timeline (`chrome://tracing` / ui.perfetto.dev).
//!
//! Mapping: rank → process (`pid`), lane → thread (`tid`, named via
//! metadata events), span → one `"ph":"X"` complete event with `ts`
//! and `dur` in microseconds, normalized to the earliest span so the
//! timeline starts at zero.  `args` carry the step and the
//! bucket/epoch tag so overlap questions ("is bucket 3's allgather in
//! flight while lane 2 selects bucket 1?") are answerable by hover.

use super::span::{lane_name, span_name, LaneDump};
use crate::util::json::{self, Value};
use std::collections::BTreeSet;

/// All drained lanes of one rank.
#[derive(Clone, Debug)]
pub struct RankDump {
    pub rank: u32,
    pub lanes: Vec<LaneDump>,
}

/// Total spans across a dump set (bench/report bookkeeping).
pub fn span_count(dumps: &[RankDump]) -> usize {
    dumps.iter().flat_map(|d| &d.lanes).map(|l| l.spans.len()).sum()
}

/// Build the `{"traceEvents": […]}` document.
pub fn chrome_trace(dumps: &[RankDump]) -> Value {
    let mut min_us = u64::MAX;
    for d in dumps {
        for l in &d.lanes {
            for s in &l.spans {
                min_us = min_us.min(s.t0_us);
            }
        }
    }
    if min_us == u64::MAX {
        min_us = 0;
    }

    let mut meta: Vec<Value> = Vec::new();
    let mut events: Vec<(u64, Value)> = Vec::new();
    let mut seen_proc: BTreeSet<u32> = BTreeSet::new();
    let mut seen_lane: BTreeSet<(u32, u32)> = BTreeSet::new();
    for d in dumps {
        if seen_proc.insert(d.rank) {
            meta.push(json::obj(vec![
                ("name", json::s("process_name")),
                ("ph", json::s("M")),
                ("pid", json::num(d.rank as f64)),
                ("tid", json::num(0.0)),
                ("args", json::obj(vec![("name", json::s(format!("rank {}", d.rank)))])),
            ]));
        }
        for l in &d.lanes {
            if seen_lane.insert((d.rank, l.lane)) {
                meta.push(json::obj(vec![
                    ("name", json::s("thread_name")),
                    ("ph", json::s("M")),
                    ("pid", json::num(d.rank as f64)),
                    ("tid", json::num(l.lane as f64)),
                    ("args", json::obj(vec![("name", json::s(lane_name(l.lane)))])),
                ]));
            }
            for sp in &l.spans {
                let ts = sp.t0_us.saturating_sub(min_us);
                let dur = sp.t1_us.saturating_sub(sp.t0_us);
                events.push((
                    ts,
                    json::obj(vec![
                        ("name", json::s(span_name(sp.phase))),
                        ("ph", json::s("X")),
                        ("pid", json::num(d.rank as f64)),
                        ("tid", json::num(l.lane as f64)),
                        ("ts", json::num(ts as f64)),
                        ("dur", json::num(dur as f64)),
                        (
                            "args",
                            json::obj(vec![
                                ("step", json::num(sp.step as f64)),
                                ("tag", json::num(sp.tag as f64)),
                            ]),
                        ),
                    ]),
                ));
            }
        }
    }
    events.sort_by_key(|(ts, _)| *ts);

    let mut all = meta;
    all.extend(events.into_iter().map(|(_, v)| v));
    json::obj(vec![
        ("traceEvents", json::arr(all)),
        ("displayTimeUnit", json::s("ms")),
    ])
}

/// Write the merged timeline to `path`.
pub fn write_chrome_trace(path: &str, dumps: &[RankDump]) -> Result<(), String> {
    std::fs::write(path, chrome_trace(dumps).to_json()).map_err(|e| format!("trace {path}: {e}"))
}

/// One named counter track: `(timestamp µs, value)` samples in record
/// order, plotted by the trace viewer as a stacked counter lane.  The
/// calibration loop emits `plan_predicted_us` / `plan_measured_us` /
/// `rank_skew` samples at every `--obs-every` window so the
/// predicted-vs-measured audit is visible *on* the timeline it audits.
#[derive(Clone, Debug)]
pub struct CounterSeries {
    pub name: String,
    pub points: Vec<(u64, f64)>,
}

/// [`chrome_trace`] plus `"ph":"C"` counter events, normalized to the
/// same time base as the span events so the tracks line up.
pub fn chrome_trace_with_counters(dumps: &[RankDump], counters: &[CounterSeries]) -> Value {
    let mut min_us = u64::MAX;
    for d in dumps {
        for l in &d.lanes {
            for s in &l.spans {
                min_us = min_us.min(s.t0_us);
            }
        }
    }
    if min_us == u64::MAX {
        min_us = 0;
    }
    let base = chrome_trace(dumps);
    let mut events: Vec<Value> = base
        .at(&["traceEvents"])
        .and_then(|e| e.as_arr())
        .map(|a| a.to_vec())
        .unwrap_or_default();
    for c in counters {
        for &(t_us, v) in &c.points {
            events.push(json::obj(vec![
                ("name", json::s(c.name.clone())),
                ("ph", json::s("C")),
                ("pid", json::num(0.0)),
                ("ts", json::num(t_us.saturating_sub(min_us) as f64)),
                ("args", json::obj(vec![("value", json::num(v))])),
            ]));
        }
    }
    json::obj(vec![("traceEvents", json::arr(events)), ("displayTimeUnit", json::s("ms"))])
}

/// Write the merged timeline with counter tracks to `path`.
pub fn write_chrome_trace_with_counters(
    path: &str,
    dumps: &[RankDump],
    counters: &[CounterSeries],
) -> Result<(), String> {
    std::fs::write(path, chrome_trace_with_counters(dumps, counters).to_json())
        .map_err(|e| format!("trace {path}: {e}"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::span::{Span, LANE_COMM_BASE, LANE_MAIN, SPAN_COMM_SPARSE, SPAN_STEP};

    fn dump() -> Vec<RankDump> {
        vec![
            RankDump {
                rank: 0,
                lanes: vec![
                    LaneDump {
                        lane: LANE_MAIN,
                        dropped: 0,
                        spans: vec![Span {
                            phase: SPAN_STEP,
                            step: 0,
                            tag: 0,
                            t0_us: 1_000,
                            t1_us: 1_900,
                        }],
                    },
                    LaneDump {
                        lane: LANE_COMM_BASE,
                        dropped: 1,
                        spans: vec![Span {
                            phase: SPAN_COMM_SPARSE,
                            step: 0,
                            tag: 2,
                            t0_us: 1_200,
                            t1_us: 1_700,
                        }],
                    },
                ],
            },
            RankDump {
                rank: 1,
                lanes: vec![LaneDump {
                    lane: LANE_MAIN,
                    dropped: 0,
                    spans: vec![Span {
                        phase: SPAN_STEP,
                        step: 0,
                        tag: 0,
                        t0_us: 1_050,
                        t1_us: 1_950,
                    }],
                }],
            },
        ]
    }

    #[test]
    fn trace_normalizes_sorts_and_names() {
        let v = chrome_trace(&dump());
        let events = v.at(&["traceEvents"]).and_then(|e| e.as_arr()).unwrap();
        // 2 process + 3 thread metadata events, then 3 X events
        let xs: Vec<_> = events
            .iter()
            .filter(|e| e.at(&["ph"]).and_then(|p| p.as_str()) == Some("X"))
            .collect();
        assert_eq!(xs.len(), 3);
        let ts: Vec<f64> = xs.iter().map(|e| e.at(&["ts"]).unwrap().as_f64().unwrap()).collect();
        assert_eq!(ts[0], 0.0, "earliest span anchors the timeline");
        assert!(ts.windows(2).all(|w| w[0] <= w[1]), "X events sorted by ts: {ts:?}");
        let names: Vec<_> = events
            .iter()
            .filter(|e| e.at(&["name"]).and_then(|n| n.as_str()) == Some("process_name"))
            .collect();
        assert_eq!(names.len(), 2, "one process_name per rank");
    }

    #[test]
    fn span_count_sums_lanes() {
        assert_eq!(span_count(&dump()), 3);
    }

    #[test]
    fn counter_events_ride_the_span_time_base() {
        let counters = vec![CounterSeries {
            name: "plan_measured_us".into(),
            points: vec![(1_100, 420.0), (1_600, 380.0), (900, 7.0)],
        }];
        let v = chrome_trace_with_counters(&dump(), &counters);
        let events = v.at(&["traceEvents"]).and_then(|e| e.as_arr()).unwrap();
        let cs: Vec<_> = events
            .iter()
            .filter(|e| e.at(&["ph"]).and_then(|p| p.as_str()) == Some("C"))
            .collect();
        assert_eq!(cs.len(), 3);
        // span min is t0 = 1_000: counters normalize against it, with
        // earlier samples clamping to 0 rather than wrapping
        let ts: Vec<f64> = cs.iter().map(|e| e.at(&["ts"]).unwrap().as_f64().unwrap()).collect();
        assert_eq!(ts, vec![100.0, 600.0, 0.0]);
        assert_eq!(cs[0].at(&["args", "value"]).unwrap().as_f64(), Some(420.0));
        // the span events themselves are untouched
        let xs = events
            .iter()
            .filter(|e| e.at(&["ph"]).and_then(|p| p.as_str()) == Some("X"))
            .count();
        assert_eq!(xs, 3);
    }
}
