//! Live metrics scrape endpoint (`--metrics-addr`): a std-only
//! `TcpListener` serving the registry's Prometheus text exposition.
//!
//! One background thread, non-blocking accept with a 10 ms idle nap,
//! HTTP/1.0 close-after-response — enough for `curl`/Prometheus, zero
//! dependencies, and a clean stop on drop (the worker owns the
//! [`Scraper`] for the run's duration).

use super::metrics::Registry;
use std::io::{Read, Write};
use std::net::TcpListener;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

/// Handle to a running scrape server; dropping it stops the thread.
pub struct Scraper {
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
    /// The bound address (resolves port 0).
    pub addr: String,
}

/// Bind `addr` and serve `reg` until the returned handle is dropped.
pub fn serve(addr: &str, reg: Arc<Registry>) -> Result<Scraper, String> {
    let listener = TcpListener::bind(addr).map_err(|e| format!("metrics bind {addr}: {e}"))?;
    let bound = listener.local_addr().map(|a| a.to_string()).unwrap_or_else(|_| addr.to_string());
    listener.set_nonblocking(true).map_err(|e| format!("metrics listener: {e}"))?;
    let stop = Arc::new(AtomicBool::new(false));
    let flag = Arc::clone(&stop);
    let handle = std::thread::spawn(move || loop {
        if flag.load(Ordering::Relaxed) {
            return;
        }
        match listener.accept() {
            Ok((mut stream, _)) => {
                // drain (part of) the request; the path is irrelevant —
                // every GET gets the full exposition
                let _ = stream.set_read_timeout(Some(Duration::from_millis(200)));
                let mut buf = [0u8; 1024];
                let _ = stream.read(&mut buf);
                let body = reg.snapshot().prometheus();
                let resp = format!(
                    "HTTP/1.0 200 OK\r\nContent-Type: text/plain; version=0.0.4\r\n\
                     Content-Length: {}\r\nConnection: close\r\n\r\n{}",
                    body.len(),
                    body
                );
                let _ = stream.write_all(resp.as_bytes());
            }
            Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(10));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(10)),
        }
    });
    Ok(Scraper { stop, handle: Some(handle), addr: bound })
}

impl Scraper {
    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Scraper {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::TcpStream;

    #[test]
    fn scrape_serves_prometheus_text() {
        let reg = Arc::new(Registry::new());
        reg.inc("steps_total", 7);
        reg.observe_us("step_latency_us", 1234);
        let mut scraper = serve("127.0.0.1:0", Arc::clone(&reg)).expect("bind");
        let mut stream = TcpStream::connect(&scraper.addr).expect("connect");
        stream.write_all(b"GET /metrics HTTP/1.0\r\n\r\n").unwrap();
        let mut resp = String::new();
        stream.read_to_string(&mut resp).unwrap();
        assert!(resp.starts_with("HTTP/1.0 200 OK"), "{resp}");
        assert!(resp.contains("text/plain; version=0.0.4"), "{resp}");
        assert!(resp.contains("steps_total 7"), "{resp}");
        assert!(resp.contains("step_latency_us_count 1"), "{resp}");
        // shutdown joins the accept thread (Drop would too)
        scraper.shutdown();
    }
}
