//! Parameter-server synchronization — the paper's §2.2 alternative
//! distributed implementation (Fig. 1, right).
//!
//! Two deployments, both built on the same in-process fabric as the
//! allreduce path so they are directly comparable:
//!
//! * [`sharded_push_pull`] — the PS sharded across the workers: push is a
//!   reduce-scatter (each rank owns a contiguous shard and sums what the
//!   others send), pull is an allgather.  The paper notes this degenerates
//!   to an allreduce; the property tests verify numerical equivalence to
//!   [`crate::collectives::allreduce_mean`], and the cost model shows the
//!   naive push/pull message pattern pays p× the latency.
//! * [`CentralServer`] — a dedicated server endpoint holding the
//!   parameters.  Synchronous mode gathers all p gradients before
//!   updating (replicas stay consistent); asynchronous mode updates on
//!   arrival (Hogwild-style stale gradients — the paper's "may not reach
//!   the same accuracy and results vary" §2.2 caveat, observable in the
//!   tests).
//!
//! The serving rationale of RedSync — quantized formats cannot ride an
//! allreduce because bit-packed values don't reduce on the fly, so
//! quantization papers target PS systems (§3) — is exercised by the
//! comparison bench `ps_vs_allreduce`.

use crate::collectives::{allgather, concat, Transport};
use crate::simnet::Machine;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread;

/// Contiguous shard bounds for `n` elements over `p` owners.
pub fn shard_bounds(n: usize, p: usize) -> Vec<(usize, usize)> {
    let base = n / p;
    let rem = n % p;
    let mut out = Vec::with_capacity(p);
    let mut start = 0;
    for i in 0..p {
        let len = base + usize::from(i < rem);
        out.push((start, start + len));
        start += len;
    }
    out
}

/// Sharded-PS synchronization: push gradients to shard owners (each rank
/// sums its own shard), pull via allgather.  In-place mean over all
/// ranks' `x`.  Numerically equivalent to `allreduce_mean`, but with the
/// PS message pattern: every rank sends p-1 shard messages (scatter) and
/// receives p-1 (gather) — 2(p-1) messages per rank vs Rabenseifner's
/// 2·lg(p).
pub fn sharded_push_pull<T: Transport>(t: &T, x: &mut [f32]) {
    let (rank, world) = (t.rank(), t.world());
    if world == 1 {
        return;
    }
    let bounds = shard_bounds(x.len(), world);

    // push: send every foreign shard to its owner
    for peer in 0..world {
        if peer == rank {
            continue;
        }
        let (lo, hi) = bounds[peer];
        t.send(peer, crate::collectives::transport::f32s_to_words(&x[lo..hi]));
    }
    // own shard: reduce the p-1 incoming contributions
    let (lo, hi) = bounds[rank];
    let mut own: Vec<f32> = x[lo..hi].to_vec();
    for peer in 0..world {
        if peer == rank {
            continue;
        }
        let msg = t.recv(peer);
        let vals = crate::collectives::transport::words_to_f32s(&msg);
        for (o, v) in own.iter_mut().zip(vals) {
            *o += v;
        }
    }
    let inv = 1.0 / world as f32;
    for o in own.iter_mut() {
        *o *= inv;
    }

    // pull: allgather the reduced shards
    let gathered = concat(allgather(t, crate::collectives::transport::f32s_to_words(&own)));
    let vals = crate::collectives::transport::words_to_f32s(&gathered);
    x.copy_from_slice(&vals[..x.len()]);
}

/// Messages between workers and the central server.
enum PsMsg {
    /// (worker rank, local gradient)
    Push(usize, Vec<f32>),
    /// worker disconnects
    Done,
}

/// Central-server deployment: one server thread owns the parameters;
/// workers push gradients and receive the (possibly stale) parameters in
/// return.
pub struct CentralServer {
    to_server: Sender<PsMsg>,
    handle: Option<thread::JoinHandle<Vec<f32>>>,
    replies: Vec<Option<Receiver<Vec<f32>>>>,
}

/// Synchronization discipline of the central server.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PsMode {
    /// Barrier: collect all p gradients, apply the average, answer all.
    Sync,
    /// Update-on-arrival (asynchronous SGD): every push is applied
    /// immediately and answered with the current parameters.
    Async,
}

/// One worker's endpoint to a [`CentralServer`].
pub struct PsWorker {
    rank: usize,
    to_server: Sender<PsMsg>,
    reply: Receiver<Vec<f32>>,
}

impl PsWorker {
    /// Push a gradient; returns the parameters the server answers with.
    pub fn push_pull(&self, grad: Vec<f32>) -> Vec<f32> {
        self.to_server
            .send(PsMsg::Push(self.rank, grad))
            .expect("server alive");
        self.reply.recv().expect("server reply")
    }
}

impl CentralServer {
    /// Spawn a server owning `params`, applying SGD with `lr` per
    /// (averaged) push, serving `world` workers in `mode`.
    pub fn spawn(params: Vec<f32>, lr: f32, world: usize, mode: PsMode) -> CentralServer {
        let (to_server, inbox) = channel::<PsMsg>();
        let mut reply_txs: Vec<Sender<Vec<f32>>> = Vec::with_capacity(world);
        let mut replies = Vec::with_capacity(world);
        for _ in 0..world {
            let (tx, rx) = channel();
            reply_txs.push(tx);
            replies.push(Some(rx));
        }
        let handle = thread::spawn(move || {
            server_loop(params, lr, world, mode, inbox, reply_txs)
        });
        CentralServer { to_server, handle: Some(handle), replies }
    }

    /// Take worker `rank`'s endpoint (once).
    pub fn worker(&mut self, rank: usize) -> PsWorker {
        PsWorker {
            rank,
            to_server: self.to_server.clone(),
            reply: self.replies[rank].take().expect("endpoint already taken"),
        }
    }

    /// Stop the server and return the final parameters.
    pub fn shutdown(mut self) -> Vec<f32> {
        let _ = self.to_server.send(PsMsg::Done);
        self.handle.take().expect("running").join().expect("server thread")
    }
}

fn server_loop(
    mut params: Vec<f32>,
    lr: f32,
    world: usize,
    mode: PsMode,
    inbox: Receiver<PsMsg>,
    replies: Vec<Sender<Vec<f32>>>,
) -> Vec<f32> {
    let mut pending: Vec<(usize, Vec<f32>)> = Vec::with_capacity(world);
    loop {
        match inbox.recv() {
            Ok(PsMsg::Push(rank, grad)) => match mode {
                PsMode::Async => {
                    // §2.2: apply immediately; the replying params already
                    // contain this worker's update but maybe not others'
                    crate::tensor::axpy(&mut params, -lr, &grad);
                    let _ = replies[rank].send(params.clone());
                }
                PsMode::Sync => {
                    pending.push((rank, grad));
                    if pending.len() == world {
                        let scale = -lr / world as f32;
                        for (_, g) in &pending {
                            crate::tensor::axpy(&mut params, scale, g);
                        }
                        for (rank, _) in pending.drain(..) {
                            let _ = replies[rank].send(params.clone());
                        }
                    }
                }
            },
            Ok(PsMsg::Done) | Err(_) => return params,
        }
    }
}

/// Cost-model comparison (the §2.2 bottleneck argument): per-iteration
/// synchronization time of a single-ported central server vs the
/// Rabenseifner allreduce, for `m_elems` parameters and `p` workers.
/// The server must serially receive p gradients and send p parameter
/// copies: `2p(α + 4Mβ)` — linear in p where allreduce is ~constant.
pub fn central_ps_time(machine: &Machine, p: usize, m_elems: f64) -> f64 {
    2.0 * p as f64 * (machine.alpha + 4.0 * m_elems * machine.beta)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::{allreduce_mean, LocalFabric};
    use crate::simnet::allreduce_time;
    use crate::util::proptest::{check, ensure};

    #[test]
    fn shard_bounds_cover_exactly() {
        for (n, p) in [(10usize, 3usize), (7, 8), (64, 4), (1, 1)] {
            let b = shard_bounds(n, p);
            assert_eq!(b.len(), p);
            assert_eq!(b[0].0, 0);
            assert_eq!(b[p - 1].1, n);
            for w in b.windows(2) {
                assert_eq!(w[0].1, w[1].0);
            }
        }
    }

    #[test]
    fn prop_sharded_ps_equals_allreduce_mean() {
        check(8, |g| {
            let world = *g.pick(&[2usize, 4, 8]);
            let n = g.size(1..1500);
            let data: Vec<Vec<f32>> = (0..world).map(|_| g.vec_normal(n, 1.0)).collect();

            let mut fabric_a = LocalFabric::new(world);
            let ps: Vec<Vec<f32>> = std::thread::scope(|s| {
                fabric_a
                    .take_all()
                    .into_iter()
                    .map(|t| {
                        let mut x = data[t.rank()].clone();
                        s.spawn(move || {
                            sharded_push_pull(&t, &mut x);
                            x
                        })
                    })
                    .collect::<Vec<_>>()
                    .into_iter()
                    .map(|h| h.join().unwrap())
                    .collect()
            });
            let mut fabric_b = LocalFabric::new(world);
            let ar: Vec<Vec<f32>> = std::thread::scope(|s| {
                fabric_b
                    .take_all()
                    .into_iter()
                    .map(|t| {
                        let mut x = data[t.rank()].clone();
                        s.spawn(move || {
                            allreduce_mean(&t, &mut x);
                            x
                        })
                    })
                    .collect::<Vec<_>>()
                    .into_iter()
                    .map(|h| h.join().unwrap())
                    .collect()
            });
            for r in 1..world {
                ensure(ps[r] == ps[0], "ps ranks disagree")?;
            }
            for (a, b) in ps[0].iter().zip(&ar[0]) {
                ensure((a - b).abs() <= 1e-5 * a.abs().max(1.0), "ps != allreduce")?;
            }
            Ok(())
        });
    }

    /// Quadratic bowl: grad = params - target.
    fn bowl_grad(params: &[f32], target: &[f32]) -> Vec<f32> {
        params.iter().zip(target).map(|(p, t)| p - t).collect()
    }

    #[test]
    fn central_sync_ps_converges_and_replicas_agree() {
        let n = 32;
        let world = 4;
        let target: Vec<f32> = (0..n).map(|i| (i as f32 * 0.37).sin()).collect();
        let mut server = CentralServer::spawn(vec![0.0; n], 0.5, world, PsMode::Sync);
        let workers: Vec<PsWorker> = (0..world).map(|r| server.worker(r)).collect();
        let finals: Vec<Vec<f32>> = std::thread::scope(|s| {
            workers
                .into_iter()
                .map(|w| {
                    let target = target.clone();
                    s.spawn(move || {
                        let mut params = vec![0.0f32; n];
                        for _ in 0..40 {
                            let g = bowl_grad(&params, &target);
                            params = w.push_pull(g);
                        }
                        params
                    })
                })
                .collect::<Vec<_>>()
                .into_iter()
                .map(|h| h.join().unwrap())
                .collect()
        });
        let final_params = server.shutdown();
        for f in &finals {
            assert_eq!(f, &finals[0], "sync PS replicas must agree");
        }
        let err: f32 = final_params
            .iter()
            .zip(&target)
            .map(|(p, t)| (p - t).abs())
            .fold(0.0, f32::max);
        assert!(err < 0.01, "did not converge: {err}");
    }

    #[test]
    fn central_async_ps_converges_on_convex_problem() {
        let n = 16;
        let world = 4;
        let target: Vec<f32> = (0..n).map(|i| (i as f32 * 0.61).cos()).collect();
        let mut server = CentralServer::spawn(vec![0.0; n], 0.2, world, PsMode::Async);
        let workers: Vec<PsWorker> = (0..world).map(|r| server.worker(r)).collect();
        std::thread::scope(|s| {
            for w in workers {
                let target = target.clone();
                s.spawn(move || {
                    let mut params = vec![0.0f32; n];
                    for _ in 0..80 {
                        let g = bowl_grad(&params, &target);
                        params = w.push_pull(g);
                    }
                });
            }
        });
        let final_params = server.shutdown();
        let err: f32 = final_params
            .iter()
            .zip(&target)
            .map(|(p, t)| (p - t).abs())
            .fold(0.0, f32::max);
        // stale gradients still converge on a convex bowl, just noisier
        assert!(err < 0.1, "async PS diverged: {err}");
    }

    #[test]
    fn central_ps_scales_worse_than_allreduce() {
        // the §2.2 claim: an independent-node PS is the bottleneck
        let m = Machine::piz_daint();
        let elems = 25e6;
        let ps8 = central_ps_time(&m, 8, elems);
        let ar8 = allreduce_time(&m, 8, elems * 4.0);
        let ps128 = central_ps_time(&m, 128, elems);
        let ar128 = allreduce_time(&m, 128, elems * 4.0);
        assert!(ps8 > ar8, "ps {ps8} vs allreduce {ar8}");
        // PS grows ~linearly with p; allreduce stays ~flat
        assert!(ps128 / ps8 > 10.0);
        assert!(ar128 / ar8 < 1.5);
    }

    #[test]
    fn worker_endpoint_taken_once() {
        let mut server = CentralServer::spawn(vec![0.0; 4], 0.1, 2, PsMode::Sync);
        let _w0 = server.worker(0);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| server.worker(0)));
        assert!(result.is_err(), "double take must panic");
        // do not shutdown: a worker endpoint is live; just drop everything
    }
}
