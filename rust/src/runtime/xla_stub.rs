//! Stand-in for the `xla` PJRT bindings when the `xla` cargo feature is
//! off (the default — the bindings need a local XLA toolchain that most
//! build environments, including CI, do not have).
//!
//! The stub keeps the exact API surface `runtime/` touches so the crate
//! compiles unchanged: a client boots (so `Runtime::new()` works and
//! transport/compression/simulation tests run everywhere), but loading or
//! executing an artifact reports a descriptive error instead of running
//! the HLO.  Build with `--features xla` and a vendored `xla` crate for
//! real PJRT execution.

const DISABLED: &str =
    "xla feature disabled: rebuild with `--features xla` and a vendored xla crate";

/// Error type mirroring `xla::Error` (stringly, like the real bindings).
#[derive(Debug)]
pub struct Error(pub String);

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

fn disabled<T>() -> Result<T, Error> {
    Err(Error(DISABLED.to_string()))
}

/// Host-side literal (stub: shape-less, value-less).
pub struct Literal;

impl Literal {
    pub fn vec1<T>(_data: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Error> {
        Ok(Literal)
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>, Error> {
        disabled()
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        disabled()
    }
}

/// Parsed HLO module (stub: never constructible from text).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, Error> {
        disabled()
    }
}

/// XLA computation wrapper.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Device-side buffer handle.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        disabled()
    }
}

/// Compiled executable handle (stub: `execute` always errors).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        disabled()
    }
}

/// PJRT client; `Rc`-based in the real bindings, hence not `Send` there —
/// the stub mirrors the per-thread ownership model but has no state.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        Ok(PjRtClient)
    }

    pub fn platform_name(&self) -> String {
        "stub-cpu (xla feature disabled)".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        disabled()
    }
}
