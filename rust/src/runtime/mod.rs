//! PJRT runtime: loads the AOT-compiled HLO text artifacts and executes
//! them — the only place where numeric compute happens at training time.
//!
//! Thread model: the `xla` crate's `PjRtClient` is `Rc`-based (not
//! `Send`), so each worker thread owns its own [`Runtime`] — mirroring
//! the paper's one-process-per-GPU deployment.  Executables are cached
//! per runtime; `make artifacts` has already paid the lowering cost, so a
//! per-worker `client.compile` of the HLO text is the only startup work.

pub mod compress_ops;
pub mod device_select;
pub mod step;

/// PJRT bindings, or the stub when the `xla` feature is off (the stub
/// boots a client but refuses to load artifacts — see `xla_stub.rs`).
#[cfg(not(feature = "xla"))]
pub(crate) mod xla_stub;
#[cfg(not(feature = "xla"))]
pub(crate) use xla_stub as xla;
#[cfg(feature = "xla")]
pub(crate) use ::xla;

pub use compress_ops::CompressOps;
pub use device_select::{DeviceSelection, DeviceSelector};
pub use step::StepRunner;

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

#[derive(Debug)]
pub enum RuntimeError {
    Xla(String),
    MissingArtifact(PathBuf),
    OutputArity { expected: usize, got: usize },
}

impl std::fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RuntimeError::Xla(msg) => write!(f, "xla: {msg}"),
            RuntimeError::MissingArtifact(p) => write!(f, "artifact not found: {}", p.display()),
            RuntimeError::OutputArity { expected, got } => {
                write!(f, "artifact output mismatch: expected {expected}, got {got}")
            }
        }
    }
}

impl std::error::Error for RuntimeError {}

impl From<xla::Error> for RuntimeError {
    fn from(e: xla::Error) -> Self {
        RuntimeError::Xla(e.to_string())
    }
}

pub type Result<T> = std::result::Result<T, RuntimeError>;

/// A typed input tensor for an executable call.
pub enum Input<'a> {
    F32(&'a [f32], &'a [usize]),
    I32(&'a [i32], &'a [usize]),
}

impl Input<'_> {
    fn to_literal(&self) -> Result<xla::Literal> {
        let lit = match self {
            Input::F32(data, shape) => {
                let l = xla::Literal::vec1(data);
                l.reshape(&shape.iter().map(|&d| d as i64).collect::<Vec<_>>())?
            }
            Input::I32(data, shape) => {
                let l = xla::Literal::vec1(data);
                l.reshape(&shape.iter().map(|&d| d as i64).collect::<Vec<_>>())?
            }
        };
        Ok(lit)
    }
}

/// One thread's PJRT CPU client + executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    cache: RefCell<HashMap<PathBuf, Rc<xla::PjRtLoadedExecutable>>>,
}

impl Runtime {
    pub fn new() -> Result<Runtime> {
        Ok(Runtime { client: xla::PjRtClient::cpu()?, cache: RefCell::new(HashMap::new()) })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO-text artifact (cached).
    pub fn load(&self, path: &Path) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.borrow().get(path) {
            return Ok(Rc::clone(exe));
        }
        if !path.exists() {
            return Err(RuntimeError::MissingArtifact(path.to_path_buf()));
        }
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().expect("artifact path must be utf-8"),
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Rc::new(self.client.compile(&comp)?);
        self.cache.borrow_mut().insert(path.to_path_buf(), Rc::clone(&exe));
        Ok(exe)
    }

    /// Execute an artifact; returns each tuple output as an f32 vec.
    /// (All our artifacts return f32 tensors lowered with
    /// `return_tuple=True`.)
    pub fn execute(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        inputs: &[Input],
    ) -> Result<Vec<Vec<f32>>> {
        let literals = inputs
            .iter()
            .map(Input::to_literal)
            .collect::<Result<Vec<_>>>()?;
        let result = exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        let parts = result.to_tuple()?;
        let mut out = Vec::with_capacity(parts.len());
        for p in parts {
            out.push(p.to_vec::<f32>()?);
        }
        Ok(out)
    }

    /// Execute with a bound on expected outputs (arity check).
    pub fn execute_expect(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        inputs: &[Input],
        expected_outputs: usize,
    ) -> Result<Vec<Vec<f32>>> {
        let out = self.execute(exe, inputs)?;
        if out.len() != expected_outputs {
            return Err(RuntimeError::OutputArity { expected: expected_outputs, got: out.len() });
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::schema::Manifest;

    fn artifacts() -> Option<Manifest> {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.json").exists() {
            Some(Manifest::load(dir).unwrap())
        } else {
            eprintln!("skipping: run `make artifacts`");
            None
        }
    }

    #[test]
    fn runtime_boots_cpu_client() {
        let rt = Runtime::new().unwrap();
        assert!(rt.platform().to_lowercase().contains("cpu") || !rt.platform().is_empty());
    }

    #[test]
    fn missing_artifact_is_error() {
        let rt = Runtime::new().unwrap();
        match rt.load(Path::new("/nonexistent/foo.hlo.txt")) {
            Err(RuntimeError::MissingArtifact(_)) => {}
            Err(e) => panic!("wrong error: {e}"),
            Ok(_) => panic!("expected MissingArtifact"),
        }
    }

    #[test]
    fn load_is_cached() {
        let Some(m) = artifacts() else { return };
        let rt = Runtime::new().unwrap();
        let p = &m.compress_ops["sgd_update"][&1024];
        let a = rt.load(p).unwrap();
        let b = rt.load(p).unwrap();
        assert!(Rc::ptr_eq(&a, &b));
    }

    #[test]
    fn sgd_update_artifact_executes() {
        let Some(m) = artifacts() else { return };
        let rt = Runtime::new().unwrap();
        let exe = rt.load(&m.compress_ops["sgd_update"][&1024]).unwrap();
        let w = vec![1.0f32; 1024];
        let g = vec![0.5f32; 1024];
        let lr = [0.1f32];
        let out = rt
            .execute_expect(
                &exe,
                &[
                    Input::F32(&w, &[1024]),
                    Input::F32(&g, &[1024]),
                    Input::F32(&lr, &[1]),
                ],
                1,
            )
            .unwrap();
        assert_eq!(out[0].len(), 1024);
        assert!(out[0].iter().all(|&v| (v - 0.95).abs() < 1e-6));
    }

    #[test]
    fn abs_stats_artifact_matches_host() {
        let Some(m) = artifacts() else { return };
        let rt = Runtime::new().unwrap();
        let exe = rt.load(&m.compress_ops["abs_stats"][&1024]).unwrap();
        let mut rng = crate::util::rng::Pcg32::seeded(3);
        let mut x = vec![0f32; 1024];
        rng.fill_normal(&mut x, 1.0);
        let out = rt.execute_expect(&exe, &[Input::F32(&x, &[1024])], 2).unwrap();
        let (mean, max) = crate::tensor::abs_mean_max(&x);
        assert!((out[0][0] - mean * 1024.0).abs() / (mean * 1024.0) < 1e-4);
        assert!((out[1][0] - max).abs() < 1e-6);
    }
}
