//! Device-side communication-set selection: the paper's Algorithms 2/3
//! executed through the L1 Pallas kernels (`abs_stats`,
//! `threshold_count`, `compress_mask`) instead of host code.
//!
//! The TPU rethink (DESIGN.md §Hardware-Adaptation): instead of the GPU's
//! serial bisection of `count_nonzero` launches, `threshold_count`
//! evaluates a *vector* of J candidate thresholds in a single pass, so a
//! bisection to ratio-resolution ε takes `log_J(1/ε)` device passes
//! rather than `log_2(1/ε)`.  `compress_mask` then produces the mask, the
//! updated residual `V·(1-mask)` and the sign-partitioned sums for
//! quantization in one fused pass; only the (tiny) masked set is
//! compacted on the host.

use super::compress_ops::CompressOps;
use super::Result;
use crate::compression::select::Selection;
use crate::tensor::SparseTensor;

/// Outcome of a device selection pass: the communication-set, the
/// threshold that produced it (reusable across iterations, §5.2.2), the
/// updated residual from the fused kernel, and the quantization stats.
pub struct DeviceSelection {
    pub sparse: SparseTensor,
    pub threshold: f32,
    /// `V·(1-mask)` — residual after removing the communication-set.
    pub residual: Vec<f32>,
    /// Sum of selected values (for mean quantization).
    pub sel_sum: f32,
}

impl DeviceSelection {
    pub fn into_selection(self) -> Selection {
        Selection { sparse: self.sparse, threshold: self.threshold }
    }
}

/// Device selection driver over one thread's [`CompressOps`].
pub struct DeviceSelector<'rt> {
    pub ops: CompressOps<'rt>,
}

impl<'rt> DeviceSelector<'rt> {
    pub fn new(ops: CompressOps<'rt>) -> Self {
        DeviceSelector { ops }
    }

    fn sign_mode(sign: Option<f32>) -> f32 {
        sign.unwrap_or(0.0)
    }

    /// Finish a pass: fused mask/residual kernel + host compaction.
    fn finish(&self, x: &[f32], threshold: f32, sign: Option<f32>) -> Result<DeviceSelection> {
        let (mask, residual, sel_sum, _cnt) =
            self.ops.compress_mask(x, threshold, Self::sign_mode(sign))?;
        let sparse = SparseTensor::compact_masked(x, &mask);
        Ok(DeviceSelection { sparse, threshold, residual, sel_sum })
    }

    /// Algorithm 2 on-device: trim with a descending-ratio threshold until
    /// ≥ k candidates survive, exact-select the top k of the (small)
    /// surviving set on the host.
    ///
    /// One `abs_stats` pass + one `threshold_count` pass (the J-vector
    /// evaluates the whole ratio ladder at once) + one `compress_mask`.
    pub fn trimmed_topk(&self, x: &[f32], k: usize, eps: f32, sign: Option<f32>) -> Result<DeviceSelection> {
        let n = x.len();
        if n == 0 || k == 0 {
            return Ok(DeviceSelection {
                sparse: SparseTensor::default(),
                threshold: f32::INFINITY,
                residual: x.to_vec(),
                sel_sum: 0.0,
            });
        }
        let (mean, max) = self.stats(x, sign)?;
        if max <= 0.0 {
            // all-zero (or all wrong-signed) residual: nothing to send
            return Ok(DeviceSelection {
                sparse: SparseTensor::default(),
                threshold: f32::INFINITY,
                residual: x.to_vec(),
                sel_sum: 0.0,
            });
        }
        // ratio ladder 1-eps, 1-2eps, ... evaluated in a single device pass
        let j = self.ops.num_thresholds;
        let ladder: Vec<f32> = (0..j)
            .map(|i| {
                let ratio = (1.0 - eps * (i + 1) as f32).max(0.0);
                mean + ratio * (max - mean)
            })
            .collect();
        let counts = self.counts(x, &ladder, sign)?;
        // first rung with enough survivors (ladder is descending in threshold)
        let pick = counts.iter().position(|&c| c >= k);
        let trim_thr = match pick {
            Some(i) => ladder[i],
            // even ratio→0 keeps fewer than k above `mean`: trim at 0
            // (keep everything positive-keyed) and let exact top-k decide
            None => 0.0,
        };
        // device: fused mask pass at the trim threshold produces the
        // candidate set and the masked residual; host exact-selects the
        // top k of the (tiny) candidate set for exact-k semantics (Alg. 2)
        let (mask, _residual, _sum, _cnt) =
            self.ops.compress_mask(x, trim_thr, Self::sign_mode(sign))?;
        let candidates = SparseTensor::compact_masked(x, &mask);
        let sel = crate::compression::select::exact_topk(&candidates.values, k, sign);
        // sel indexes into `candidates`; map back to original positions
        let mut pairs: Vec<(u32, f32)> = sel
            .sparse
            .indices
            .iter()
            .map(|&ci| {
                let i = candidates.indices[ci as usize];
                (i, x[i as usize])
            })
            .collect();
        pairs.sort_unstable_by_key(|&(i, _)| i);
        let sel_sum = pairs.iter().map(|&(_, v)| v).sum();
        let (idx, vals): (Vec<u32>, Vec<f32>) = pairs.into_iter().unzip();
        let chosen = SparseTensor::new(idx, vals);
        let mut residual = x.to_vec();
        chosen.zero_at(&mut residual);
        Ok(DeviceSelection { sparse: chosen, threshold: sel.threshold, residual, sel_sum })
    }

    /// Algorithm 3 on-device: J-way threshold bisection until the count
    /// lands in [k, 2k] (or the bracket is narrower than `eps`).
    pub fn threshold_binary_search(
        &self,
        x: &[f32],
        k: usize,
        eps: f32,
        max_passes: usize,
        sign: Option<f32>,
    ) -> Result<DeviceSelection> {
        let n = x.len();
        if n == 0 || k == 0 {
            return Ok(DeviceSelection {
                sparse: SparseTensor::default(),
                threshold: f32::INFINITY,
                residual: x.to_vec(),
                sel_sum: 0.0,
            });
        }
        let (mean, max) = self.stats(x, sign)?;
        if max <= 0.0 {
            return Ok(DeviceSelection {
                sparse: SparseTensor::default(),
                threshold: f32::INFINITY,
                residual: x.to_vec(),
                sel_sum: 0.0,
            });
        }
        let j = self.ops.num_thresholds;
        let (mut lo, mut hi) = (0.0f32, 1.0f32); // ratio bracket
        let mut best = mean; // threshold at ratio 0
        for _ in 0..max_passes {
            if hi - lo <= eps {
                break;
            }
            // J interior points of the bracket, descending threshold order
            let ladder: Vec<f32> = (0..j)
                .map(|i| {
                    let r = hi - (hi - lo) * (i + 1) as f32 / (j + 1) as f32;
                    mean + r * (max - mean)
                })
                .collect();
            let counts = self.counts(x, &ladder, sign)?;
            // find the highest threshold with count in [k, 2k]
            if let Some(i) = counts.iter().position(|&c| c >= k && c <= 2 * k) {
                best = ladder[i];
                return self.finish(x, best, sign);
            }
            // bracket: last rung with count < k and first with count > 2k
            let mut new_hi = hi;
            let mut new_lo = lo;
            for (i, &c) in counts.iter().enumerate() {
                let r = hi - (hi - lo) * (i + 1) as f32 / (j + 1) as f32;
                if c < k {
                    new_hi = r; // too strict: threshold can come down
                } else if c > 2 * k {
                    new_lo = new_lo.max(r); // too loose
                    break;
                }
            }
            if new_hi <= new_lo {
                best = mean + new_hi * (max - mean);
                break;
            }
            hi = new_hi;
            lo = new_lo;
            best = mean + lo * (max - mean);
        }
        self.finish(x, best, sign)
    }

    fn stats(&self, x: &[f32], sign: Option<f32>) -> Result<(f32, f32)> {
        match sign {
            // magnitude stats come straight from the kernel
            None => self.ops.abs_stats(x),
            // signed stats need max(s·x, 0): cheap host fallback (the L1
            // kernel computes |x| stats; signed quantized layers re-search
            // every iteration anyway per §6.4)
            Some(s) => {
                let mut sum = 0f64;
                let mut max = 0f32;
                for &v in x {
                    let kx = (s * v).max(0.0);
                    sum += kx as f64;
                    max = max.max(kx);
                }
                Ok(((sum / x.len() as f64) as f32, max))
            }
        }
    }

    fn counts(&self, x: &[f32], thresholds: &[f32], sign: Option<f32>) -> Result<Vec<usize>> {
        match sign {
            None => self.ops.threshold_count(x, thresholds),
            Some(s) => Ok(thresholds
                .iter()
                .map(|&t| crate::tensor::count_above_signed(x, t, s))
                .collect()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::schema::Manifest;
    use crate::runtime::Runtime;
    use crate::util::rng::Pcg32;
    use std::path::PathBuf;

    fn setup() -> Option<(Runtime, Manifest)> {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: run `make artifacts`");
            return None;
        }
        Some((Runtime::new().unwrap(), Manifest::load(dir).unwrap()))
    }

    fn randn(n: usize, seed: u64) -> Vec<f32> {
        let mut r = Pcg32::seeded(seed);
        let mut v = vec![0f32; n];
        r.fill_normal(&mut v, 1.0);
        v
    }

    #[test]
    fn device_trimmed_matches_host_exact_topk() {
        let Some((rt, m)) = setup() else { return };
        let sel = DeviceSelector::new(CompressOps::new(&rt, &m).unwrap());
        let x = randn(4000, 1);
        let k = 40;
        let d = sel.trimmed_topk(&x, k, 0.2, None).unwrap();
        assert_eq!(d.sparse.len(), k);
        let host = crate::compression::select::exact_topk(&x, k, None);
        assert_eq!(d.sparse.indices, host.sparse.indices);
        // residual zeroed exactly at the selected indices
        for &i in &d.sparse.indices {
            assert_eq!(d.residual[i as usize], 0.0);
        }
        let zeros = d.residual.iter().filter(|&&v| v == 0.0).count();
        assert!(zeros >= k);
    }

    #[test]
    fn device_binary_search_in_k_2k() {
        let Some((rt, m)) = setup() else { return };
        let sel = DeviceSelector::new(CompressOps::new(&rt, &m).unwrap());
        let x = randn(60_000, 2);
        let k = 60;
        let d = sel.threshold_binary_search(&x, k, 1e-3, 16, None).unwrap();
        assert!(
            d.sparse.len() >= k && d.sparse.len() <= 2 * k + 2,
            "selected {} for k={k}",
            d.sparse.len()
        );
        // every selected magnitude is >= every unselected magnitude... at
        // least the threshold property must hold:
        for (&i, &v) in d.sparse.indices.iter().zip(&d.sparse.values) {
            assert!(v.abs() > d.threshold, "idx {i}");
        }
    }

    #[test]
    fn device_signed_selection_single_signed() {
        let Some((rt, m)) = setup() else { return };
        let sel = DeviceSelector::new(CompressOps::new(&rt, &m).unwrap());
        let x = randn(5000, 3);
        let d = sel.trimmed_topk(&x, 25, 0.2, Some(1.0)).unwrap();
        assert_eq!(d.sparse.len(), 25);
        assert!(d.sparse.values.iter().all(|&v| v > 0.0));
        let dneg = sel.trimmed_topk(&x, 25, 0.2, Some(-1.0)).unwrap();
        assert!(dneg.sparse.values.iter().all(|&v| v < 0.0));
        assert!(dneg.sel_sum < 0.0);
    }

    #[test]
    fn device_zero_residual_selects_nothing() {
        let Some((rt, m)) = setup() else { return };
        let sel = DeviceSelector::new(CompressOps::new(&rt, &m).unwrap());
        let x = vec![0f32; 2048];
        let d = sel.trimmed_topk(&x, 10, 0.2, None).unwrap();
        assert_eq!(d.sparse.len(), 0);
        let d = sel.threshold_binary_search(&x, 10, 1e-3, 8, None).unwrap();
        assert_eq!(d.sparse.len(), 0);
    }
}
