//! Model step execution: drives one model's train-step and eval HLO
//! artifacts from the coordinator hot loop.
//!
//! The artifact contract (see `python/compile/aot.py`):
//!
//! * step: `fn(*params, *inputs) -> (loss[1], *grads)` — grads in the same
//!   order as `schema.params`.
//! * eval (lm): `fn(*params, tokens, targets) -> (loss[1],)`
//! * eval (mlp): `fn(*params, x) -> (logits,)`

use super::{xla, Input, Result, Runtime, RuntimeError};
use crate::models::schema::ModelSchema;
use std::rc::Rc;

/// A model's compiled step + eval executables for one runtime thread.
pub struct StepRunner {
    pub schema: ModelSchema,
    step: Rc<xla::PjRtLoadedExecutable>,
    eval: Rc<xla::PjRtLoadedExecutable>,
}

/// Mini-batch inputs for one step, already in the model's layout.
#[derive(Clone, Debug)]
pub enum Batch {
    /// (tokens, targets), each `batch*seq` i32 — LM models.
    Lm { tokens: Vec<i32>, targets: Vec<i32> },
    /// (x `batch*in_dim` f32, y `batch` i32) — MLP models.
    Mlp { x: Vec<f32>, y: Vec<i32> },
}

impl StepRunner {
    pub fn new(rt: &Runtime, schema: &ModelSchema) -> Result<StepRunner> {
        Ok(StepRunner {
            schema: schema.clone(),
            step: rt.load(&schema.file)?,
            eval: rt.load(&schema.eval_file)?,
        })
    }

    fn push_params<'a>(&'a self, params: &'a [Vec<f32>], inputs: &mut Vec<Input<'a>>) {
        for (spec, buf) in self.schema.params.iter().zip(params) {
            debug_assert_eq!(buf.len(), spec.size(), "param {} size", spec.name);
            inputs.push(Input::F32(buf, &spec.shape));
        }
    }

    fn batch_inputs<'a>(&'a self, batch: &'a Batch, inputs: &mut Vec<Input<'a>>) {
        match batch {
            Batch::Lm { tokens, targets } => {
                inputs.push(Input::I32(tokens, &self.schema.inputs[0].shape));
                inputs.push(Input::I32(targets, &self.schema.inputs[1].shape));
            }
            Batch::Mlp { x, y } => {
                inputs.push(Input::F32(x, &self.schema.inputs[0].shape));
                inputs.push(Input::I32(y, &self.schema.inputs[1].shape));
            }
        }
    }

    /// Run one forward+backward step: returns `(loss, grads)` with one
    /// grad buffer per parameter, in schema order.
    pub fn step(&self, rt: &Runtime, params: &[Vec<f32>], batch: &Batch) -> Result<(f32, Vec<Vec<f32>>)> {
        let mut inputs = Vec::with_capacity(self.schema.params.len() + 2);
        self.push_params(params, &mut inputs);
        self.batch_inputs(batch, &mut inputs);
        let mut out = rt.execute_expect(&self.step, &inputs, self.schema.params.len() + 1)?;
        let grads = out.split_off(1);
        let loss = out[0][0];
        if !loss.is_finite() {
            return Err(RuntimeError::Xla(format!("non-finite loss {loss}")));
        }
        Ok((loss, grads))
    }

    /// Eval an LM model: held-out mean token cross-entropy.
    pub fn eval_lm(&self, rt: &Runtime, params: &[Vec<f32>], batch: &Batch) -> Result<f32> {
        let mut inputs = Vec::with_capacity(self.schema.params.len() + 2);
        self.push_params(params, &mut inputs);
        self.batch_inputs(batch, &mut inputs);
        let out = rt.execute_expect(&self.eval, &inputs, 1)?;
        Ok(out[0][0])
    }

    /// Eval an MLP model: returns flat logits `[batch, classes]` for the
    /// configured batch shape.
    pub fn eval_mlp_logits(&self, rt: &Runtime, params: &[Vec<f32>], x: &[f32]) -> Result<Vec<f32>> {
        let mut inputs = Vec::with_capacity(self.schema.params.len() + 1);
        self.push_params(params, &mut inputs);
        inputs.push(Input::F32(x, &self.schema.inputs[0].shape));
        let out = rt.execute_expect(&self.eval, &inputs, 1)?;
        Ok(out[0].clone())
    }

    /// MLP classification accuracy over `(x, y)` batches sliced out of a
    /// full dataset (only whole batches are evaluated).
    pub fn eval_mlp_accuracy(
        &self,
        rt: &Runtime,
        params: &[Vec<f32>],
        xs: &[f32],
        ys: &[i32],
    ) -> Result<f32> {
        let b = self.schema.cfg("batch").unwrap_or(1);
        let d = self.schema.cfg("in_dim").unwrap_or(1);
        let c = self.schema.cfg("classes").unwrap_or(1);
        let n_batches = ys.len() / b;
        if n_batches == 0 {
            return Ok(0.0);
        }
        let mut correct = 0usize;
        for bi in 0..n_batches {
            let x = &xs[bi * b * d..(bi + 1) * b * d];
            let logits = self.eval_mlp_logits(rt, params, x)?;
            for i in 0..b {
                let row = &logits[i * c..(i + 1) * c];
                let pred = row
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(j, _)| j)
                    .unwrap();
                if pred as i32 == ys[bi * b + i] {
                    correct += 1;
                }
            }
        }
        Ok(correct as f32 / (n_batches * b) as f32)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::schema::Manifest;
    use std::path::PathBuf;

    fn setup() -> Option<(Runtime, Manifest)> {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: run `make artifacts`");
            return None;
        }
        Some((Runtime::new().unwrap(), Manifest::load(dir).unwrap()))
    }

    fn lm_batch(schema: &ModelSchema, seed: u64) -> Batch {
        let b = schema.cfg("batch").unwrap();
        let s = schema.cfg("seq").unwrap();
        let v = schema.cfg("vocab").unwrap() as u32;
        let mut rng = crate::util::rng::Pcg32::seeded(seed);
        Batch::Lm {
            tokens: (0..b * s).map(|_| rng.below(v) as i32).collect(),
            targets: (0..b * s).map(|_| rng.below(v) as i32).collect(),
        }
    }

    #[test]
    fn lm_step_produces_loss_and_grads() {
        let Some((rt, m)) = setup() else { return };
        let schema = &m.models["lm_tiny"];
        let runner = StepRunner::new(&rt, schema).unwrap();
        let params = schema.init_params(7);
        let (loss, grads) = runner.step(&rt, &params, &lm_batch(schema, 1)).unwrap();
        // random targets over vocab 64: loss near ln(64) ≈ 4.16
        assert!(loss > 2.0 && loss < 8.0, "loss {loss}");
        assert_eq!(grads.len(), schema.params.len());
        for (g, p) in grads.iter().zip(&schema.params) {
            assert_eq!(g.len(), p.size(), "{}", p.name);
        }
        // embedding grad should be nonzero
        assert!(grads[0].iter().any(|&v| v != 0.0));
    }

    #[test]
    fn lm_sgd_direction_reduces_loss() {
        let Some((rt, m)) = setup() else { return };
        let schema = &m.models["lm_tiny"];
        let runner = StepRunner::new(&rt, schema).unwrap();
        let mut params = schema.init_params(7);
        let batch = lm_batch(schema, 2);
        let (l0, grads) = runner.step(&rt, &params, &batch).unwrap();
        for (p, g) in params.iter_mut().zip(&grads) {
            crate::tensor::axpy(p, -0.5, g);
        }
        let (l1, _) = runner.step(&rt, &params, &batch).unwrap();
        assert!(l1 < l0, "loss did not decrease: {l0} -> {l1}");
    }

    #[test]
    fn eval_matches_step_loss() {
        let Some((rt, m)) = setup() else { return };
        let schema = &m.models["lm_tiny"];
        let runner = StepRunner::new(&rt, schema).unwrap();
        let params = schema.init_params(3);
        let batch = lm_batch(schema, 5);
        let (l_step, _) = runner.step(&rt, &params, &batch).unwrap();
        let l_eval = runner.eval_lm(&rt, &params, &batch).unwrap();
        assert!((l_step - l_eval).abs() < 1e-4, "{l_step} vs {l_eval}");
    }

    #[test]
    fn mlp_step_and_accuracy() {
        let Some((rt, m)) = setup() else { return };
        let schema = &m.models["mlp_tiny"];
        let runner = StepRunner::new(&rt, schema).unwrap();
        let mut params = schema.init_params(11);
        let ds = crate::data::ClusterDataset::new(
            256,
            schema.cfg("in_dim").unwrap(),
            schema.cfg("classes").unwrap(),
            3.0,
            42,
        );
        let acc0 = {
            let (xs, ys) = ds.all();
            runner.eval_mlp_accuracy(&rt, &params, xs, ys).unwrap()
        };
        for step in 0..60 {
            let (x, y) = ds.batch(0, 1, step, schema.cfg("batch").unwrap());
            let (_, grads) = runner.step(&rt, &params, &Batch::Mlp { x, y }).unwrap();
            for (p, g) in params.iter_mut().zip(&grads) {
                crate::tensor::axpy(p, -0.1, g);
            }
        }
        let (xs, ys) = ds.all();
        let acc = runner.eval_mlp_accuracy(&rt, &params, xs, ys).unwrap();
        assert!(acc > acc0.max(0.5), "train did not improve accuracy: {acc0} -> {acc}");
    }
}
