//! Device-side compression operators: the L1 Pallas kernels, executed
//! through their per-bucket HLO artifacts.
//!
//! Layers are padded with zeros up to the next bucket size (the tensor-
//! fusion analogue that keeps the artifact count bounded).  Zero padding
//! is invisible to every op: |0| is never `> thr` for the non-negative
//! thresholds the selection pipeline produces, and stats/counters ignore
//! zeros by construction.

use super::{Input, Result, Runtime, RuntimeError};
use crate::models::schema::Manifest;
use std::collections::BTreeMap;
use std::path::PathBuf;

/// Handle to the per-bucket compression artifacts for one runtime thread.
pub struct CompressOps<'rt> {
    rt: &'rt Runtime,
    abs_stats: BTreeMap<usize, PathBuf>,
    threshold_count: BTreeMap<usize, PathBuf>,
    compress_mask: BTreeMap<usize, PathBuf>,
    sgd_update: BTreeMap<usize, PathBuf>,
    /// Optional: artifacts built before the op existed still load.
    momentum_accum: Option<BTreeMap<usize, PathBuf>>,
    pub num_thresholds: usize,
    /// reusable padding buffer
    scratch: std::cell::RefCell<Vec<f32>>,
}

impl<'rt> CompressOps<'rt> {
    pub fn new(rt: &'rt Runtime, manifest: &Manifest) -> Result<Self> {
        let get = |op: &str| -> Result<BTreeMap<usize, PathBuf>> {
            manifest
                .compress_ops
                .get(op)
                .cloned()
                .ok_or_else(|| RuntimeError::MissingArtifact(PathBuf::from(op)))
        };
        Ok(CompressOps {
            rt,
            abs_stats: get("abs_stats")?,
            threshold_count: get("threshold_count")?,
            compress_mask: get("compress_mask")?,
            sgd_update: get("sgd_update")?,
            momentum_accum: manifest.compress_ops.get("momentum_accum").cloned(),
            num_thresholds: manifest.num_thresholds,
            scratch: std::cell::RefCell::new(Vec::new()),
        })
    }

    /// True when the fused momentum-correction artifacts are available.
    pub fn has_momentum_accum(&self) -> bool {
        self.momentum_accum.is_some()
    }

    /// Device fused momentum-correction accumulation (Alg. 4 lines
    /// 11-19): returns `(v', u')` where `u' = momentum·u + g` and
    /// `v' = v + u' + nesterov·g`.
    pub fn momentum_accum(
        &self,
        v: &[f32],
        u: &[f32],
        g: &[f32],
        momentum: f32,
        nesterov: bool,
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        assert_eq!(v.len(), u.len());
        assert_eq!(v.len(), g.len());
        let map = self
            .momentum_accum
            .as_ref()
            .ok_or_else(|| RuntimeError::MissingArtifact(PathBuf::from("momentum_accum")))?;
        let (bucket, path) = Self::bucket(map, v.len())?;
        let exe = self.rt.load(path)?;
        let pad = |x: &[f32]| {
            let mut p = x.to_vec();
            p.resize(bucket, 0.0);
            p
        };
        let (vp, up, gp) = (pad(v), pad(u), pad(g));
        let out = self.rt.execute_expect(
            &exe,
            &[
                Input::F32(&vp, &[bucket]),
                Input::F32(&up, &[bucket]),
                Input::F32(&gp, &[bucket]),
                Input::F32(&[momentum], &[1]),
                Input::F32(&[if nesterov { 1.0 } else { 0.0 }], &[1]),
            ],
            2,
        )?;
        let mut new_v = out[0].clone();
        new_v.truncate(v.len());
        let mut new_u = out[1].clone();
        new_u.truncate(u.len());
        Ok((new_v, new_u))
    }

    fn bucket(map: &BTreeMap<usize, PathBuf>, n: usize) -> Result<(usize, &PathBuf)> {
        map.range(n..)
            .next()
            .map(|(&b, p)| (b, p))
            .ok_or_else(|| RuntimeError::MissingArtifact(PathBuf::from(format!("bucket>={n}"))))
    }

    /// Largest supported tensor size.
    pub fn max_bucket(&self) -> usize {
        self.abs_stats.keys().max().copied().unwrap_or(0)
    }

    fn padded(&self, x: &[f32], bucket: usize) -> std::cell::Ref<'_, Vec<f32>> {
        {
            let mut s = self.scratch.borrow_mut();
            s.clear();
            s.extend_from_slice(x);
            s.resize(bucket, 0.0);
        }
        self.scratch.borrow()
    }

    /// Device `abs_stats`: (mean |x|, max |x|).  Mean uses the *real*
    /// element count, not the padded bucket size.
    pub fn abs_stats(&self, x: &[f32]) -> Result<(f32, f32)> {
        let (bucket, path) = Self::bucket(&self.abs_stats, x.len())?;
        let exe = self.rt.load(path)?;
        let padded = self.padded(x, bucket);
        let out = self.rt.execute_expect(&exe, &[Input::F32(&padded, &[bucket])], 2)?;
        drop(padded);
        Ok((out[0][0] / x.len() as f32, out[1][0]))
    }

    /// Device `threshold_count`: counts of |x| > t_j for J thresholds in a
    /// single pass.
    pub fn threshold_count(&self, x: &[f32], thresholds: &[f32]) -> Result<Vec<usize>> {
        assert_eq!(thresholds.len(), self.num_thresholds, "J mismatch with artifact");
        let (bucket, path) = Self::bucket(&self.threshold_count, x.len())?;
        let exe = self.rt.load(path)?;
        let padded = self.padded(x, bucket);
        let out = self.rt.execute_expect(
            &exe,
            &[
                Input::F32(&padded, &[bucket]),
                Input::F32(thresholds, &[thresholds.len()]),
            ],
            1,
        )?;
        drop(padded);
        Ok(out[0].iter().map(|&c| c as usize).collect())
    }

    /// Device `compress_mask`: returns (mask, residual, sel_sum, sel_cnt),
    /// truncated back to the real length.
    /// `sign_mode`: 0.0 magnitude / ±1.0 signed (quantized RGC).
    pub fn compress_mask(
        &self,
        x: &[f32],
        threshold: f32,
        sign_mode: f32,
    ) -> Result<(Vec<f32>, Vec<f32>, f32, f32)> {
        let (bucket, path) = Self::bucket(&self.compress_mask, x.len())?;
        let exe = self.rt.load(path)?;
        let padded = self.padded(x, bucket);
        let out = self.rt.execute_expect(
            &exe,
            &[
                Input::F32(&padded, &[bucket]),
                Input::F32(&[threshold], &[1]),
                Input::F32(&[sign_mode], &[1]),
            ],
            4,
        )?;
        drop(padded);
        let mut mask = out[0].clone();
        mask.truncate(x.len());
        let mut residual = out[1].clone();
        residual.truncate(x.len());
        Ok((mask, residual, out[2][0], out[3][0]))
    }

    /// Device fused dense SGD step: w - lr·g.
    pub fn sgd_update(&self, w: &[f32], g: &[f32], lr: f32) -> Result<Vec<f32>> {
        assert_eq!(w.len(), g.len());
        let (bucket, path) = Self::bucket(&self.sgd_update, w.len())?;
        let exe = self.rt.load(path)?;
        let mut wp = w.to_vec();
        wp.resize(bucket, 0.0);
        let mut gp = g.to_vec();
        gp.resize(bucket, 0.0);
        let out = self.rt.execute_expect(
            &exe,
            &[
                Input::F32(&wp, &[bucket]),
                Input::F32(&gp, &[bucket]),
                Input::F32(&[lr], &[1]),
            ],
            1,
        )?;
        let mut new_w = out[0].clone();
        new_w.truncate(w.len());
        Ok(new_w)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn setup() -> Option<(Runtime, Manifest)> {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: run `make artifacts`");
            return None;
        }
        Some((Runtime::new().unwrap(), Manifest::load(dir).unwrap()))
    }

    fn randn(n: usize, seed: u64) -> Vec<f32> {
        let mut r = Pcg32::seeded(seed);
        let mut v = vec![0f32; n];
        r.fill_normal(&mut v, 1.0);
        v
    }

    #[test]
    fn abs_stats_with_padding() {
        let Some((rt, m)) = setup() else { return };
        let ops = CompressOps::new(&rt, &m).unwrap();
        // 700 elements -> padded to 1024 bucket
        let x = randn(700, 1);
        let (mean, max) = ops.abs_stats(&x).unwrap();
        let (hm, hx) = crate::tensor::abs_mean_max(&x);
        assert!((mean - hm).abs() / hm < 1e-4, "{mean} vs {hm}");
        assert!((max - hx).abs() < 1e-6);
    }

    #[test]
    fn threshold_count_ignores_padding() {
        let Some((rt, m)) = setup() else { return };
        let ops = CompressOps::new(&rt, &m).unwrap();
        let x = randn(900, 2);
        let thresholds: Vec<f32> =
            (0..ops.num_thresholds).map(|i| i as f32 * 0.2).collect();
        let counts = ops.threshold_count(&x, &thresholds).unwrap();
        for (c, t) in counts.iter().zip(&thresholds) {
            assert_eq!(*c, crate::tensor::count_above(&x, *t), "t={t}");
        }
    }

    #[test]
    fn compress_mask_roundtrip() {
        let Some((rt, m)) = setup() else { return };
        let ops = CompressOps::new(&rt, &m).unwrap();
        let x = randn(1000, 3);
        let (mask, residual, sum, cnt) = ops.compress_mask(&x, 0.8, 0.0).unwrap();
        assert_eq!(mask.len(), 1000);
        let host_cnt = crate::tensor::count_above(&x, 0.8);
        assert_eq!(cnt as usize, host_cnt);
        // mask*x + residual == x
        for i in 0..1000 {
            assert!((mask[i] * x[i] + residual[i] - x[i]).abs() < 1e-6);
        }
        let host_sum: f32 = x.iter().filter(|v| v.abs() > 0.8).sum();
        assert!((sum - host_sum).abs() < 1e-3);
    }

    #[test]
    fn compress_mask_signed() {
        let Some((rt, m)) = setup() else { return };
        let ops = CompressOps::new(&rt, &m).unwrap();
        let x = randn(512, 4);
        let (mask, _, sum, cnt) = ops.compress_mask(&x, 0.5, -1.0).unwrap();
        for (i, &mk) in mask.iter().enumerate() {
            if mk > 0.5 {
                assert!(x[i] < -0.5);
            }
        }
        assert!(cnt > 0.0 && sum < 0.0);
    }

    #[test]
    fn sgd_update_matches_host() {
        let Some((rt, m)) = setup() else { return };
        let ops = CompressOps::new(&rt, &m).unwrap();
        let w = randn(300, 5);
        let g = randn(300, 6);
        let out = ops.sgd_update(&w, &g, 0.01).unwrap();
        for i in 0..300 {
            assert!((out[i] - (w[i] - 0.01 * g[i])).abs() < 1e-6);
        }
    }

    #[test]
    fn momentum_accum_matches_host_residual() {
        let Some((rt, m)) = setup() else { return };
        let ops = CompressOps::new(&rt, &m).unwrap();
        if !ops.has_momentum_accum() {
            eprintln!("skipping: artifacts predate momentum_accum");
            return;
        }
        use crate::compression::{Accumulation, ResidualState};
        for (acc, momentum, nesterov) in [
            (Accumulation::Sgd, 0.0f32, false),
            (Accumulation::Momentum { momentum: 0.9 }, 0.9, false),
            (Accumulation::Nesterov { momentum: 0.9 }, 0.9, true),
        ] {
            let mut host = ResidualState::new(700, acc);
            let mut dv = vec![0f32; 700];
            let mut du = vec![0f32; 700];
            for step in 0..3 {
                let g = randn(700, 40 + step);
                host.accumulate(&g);
                let (v, u) = ops.momentum_accum(&dv, &du, &g, momentum, nesterov).unwrap();
                dv = v;
                du = u;
            }
            for i in 0..700 {
                assert!(
                    (dv[i] - host.residual()[i]).abs() < 1e-4,
                    "{acc:?} v[{i}]: {} vs {}",
                    dv[i],
                    host.residual()[i]
                );
                // u is unused (and not maintained host-side) under Sgd
                if momentum != 0.0 {
                    assert!(
                        (du[i] - host.momentum_buf()[i]).abs() < 1e-4,
                        "{acc:?} u[{i}]"
                    );
                }
            }
        }
    }

    #[test]
    fn oversized_tensor_rejected() {
        let Some((rt, m)) = setup() else { return };
        let ops = CompressOps::new(&rt, &m).unwrap();
        let x = vec![1.0f32; ops.max_bucket() + 1];
        assert!(ops.abs_stats(&x).is_err());
    }
}
