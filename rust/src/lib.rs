//! # RedSync
//!
//! Reproduction of *"RedSync: Reducing Synchronization Traffic for
//! Distributed Deep Learning"* (Fang, Fu, Yang, Hsieh; JPDC 2019) as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the distributed data-parallel coordinator:
//!   residual gradient compression, sparse allgather synchronization,
//!   cost-model-driven per-layer policy, worker orchestration — over an
//!   in-process fabric (threads) or the [`net`] TCP fabric (one process
//!   per rank, `redsync launch`).
//! * **L2 (python/compile/model.py)** — jax train-step graphs, AOT-lowered
//!   to HLO text under `artifacts/`.
//! * **L1 (python/compile/kernels/)** — Pallas kernels for the selection
//!   hot-spot, lowered into the same artifacts.
//!
//! Python never runs at training time: [`runtime`] loads the artifacts via
//! PJRT (xla crate) and the coordinator drives everything from Rust.
//!
//! See DESIGN.md for the full system inventory and the experiment index
//! mapping every figure/table of the paper to a bench target.

pub mod collectives;
pub mod compression;
pub mod config;
pub mod coordinator;
pub mod costmodel;
pub mod data;
pub mod elastic;
pub mod models;
pub mod net;
pub mod obs;
pub mod optim;
pub mod pipeline;
pub mod ps;
pub mod runtime;
pub mod simnet;
pub mod tensor;
pub mod util;
