//! Warm-up schedule (§5.7).
//!
//! DGC warms up by exponentially decaying the density (25%, 6.25%, …,
//! 0.1%) over the first epochs.  RedSync observes that on large clusters
//! even 1.5625% density already needs ≥ dense bandwidth, so it instead
//! runs *dense allreduce* for the warm-up epochs and switches to the
//! target density afterwards.  Both schedules are provided (the DGC one
//! serves as an ablation).

/// Density schedule across training epochs.
#[derive(Clone, Debug, PartialEq)]
pub enum WarmupSchedule {
    /// No warm-up: target density from step one.
    None { density: f64 },
    /// RedSync: dense allreduce (density = 1) for `epochs`, then target.
    DenseEpochs { epochs: usize, density: f64 },
    /// DGC: exponential decay from `start` by `factor` per epoch until
    /// reaching `density`.
    Exponential { start: f64, factor: f64, density: f64 },
}

impl WarmupSchedule {
    /// Density to use at `epoch` (0-based).
    pub fn density_at(&self, epoch: usize) -> f64 {
        match self {
            WarmupSchedule::None { density } => *density,
            WarmupSchedule::DenseEpochs { epochs, density } => {
                if epoch < *epochs {
                    1.0
                } else {
                    *density
                }
            }
            WarmupSchedule::Exponential { start, factor, density } => {
                (start * factor.powi(epoch as i32)).max(*density)
            }
        }
    }

    /// True if this epoch should bypass compression entirely (dense sync).
    pub fn is_dense_at(&self, epoch: usize) -> bool {
        self.density_at(epoch) >= 1.0
    }

    /// The paper's recommended DGC-style decay: 25%, 6.25%, 1.5625%,
    /// 0.4%, 0.1%.
    pub fn dgc_default() -> WarmupSchedule {
        WarmupSchedule::Exponential { start: 0.25, factor: 0.25, density: 1e-3 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn none_is_flat() {
        let s = WarmupSchedule::None { density: 1e-3 };
        assert_eq!(s.density_at(0), 1e-3);
        assert_eq!(s.density_at(99), 1e-3);
        assert!(!s.is_dense_at(0));
    }

    #[test]
    fn dense_epochs_switch() {
        let s = WarmupSchedule::DenseEpochs { epochs: 5, density: 1e-3 };
        assert!(s.is_dense_at(0) && s.is_dense_at(4));
        assert!(!s.is_dense_at(5));
        assert_eq!(s.density_at(5), 1e-3);
    }

    #[test]
    fn dgc_sequence_matches_paper() {
        let s = WarmupSchedule::dgc_default();
        let expect = [0.25, 0.0625, 0.015625];
        for (e, &d) in expect.iter().enumerate() {
            assert!((s.density_at(e) - d).abs() < 1e-12, "epoch {e}");
        }
        // paper's listed step 4 is 0.4% ~ 0.39% from exact decay
        assert!((s.density_at(3) - 0.00390625).abs() < 1e-12);
        assert_eq!(s.density_at(4), 1e-3); // floored at target
        assert_eq!(s.density_at(10), 1e-3);
    }

    #[test]
    fn exponential_never_below_target() {
        let s = WarmupSchedule::Exponential { start: 0.5, factor: 0.1, density: 0.01 };
        for e in 0..20 {
            assert!(s.density_at(e) >= 0.01);
        }
    }
}
