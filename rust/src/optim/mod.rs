//! Optimizers and training-correctness machinery (§5.7 / Alg. 4):
//! SGD/momentum/Nesterov, gradient clipping (global + DGC local N^{-1/2}),
//! and the warm-up density schedule.

pub mod clip;
pub mod warmup;

pub use clip::{clip_by_global_norm, local_clip_factor};
pub use warmup::WarmupSchedule;

use crate::tensor::axpy;

/// Optimizer flavor (mirrors `compression::Accumulation` for the
/// *uncompressed* / dense path).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Optimizer {
    Sgd,
    Momentum { momentum: f32 },
    Nesterov { momentum: f32 },
}

impl Optimizer {
    pub fn momentum(&self) -> f32 {
        match self {
            Optimizer::Sgd => 0.0,
            Optimizer::Momentum { momentum } | Optimizer::Nesterov { momentum } => *momentum,
        }
    }

    pub fn accumulation(&self) -> crate::compression::Accumulation {
        match *self {
            Optimizer::Sgd => crate::compression::Accumulation::Sgd,
            Optimizer::Momentum { momentum } => {
                crate::compression::Accumulation::Momentum { momentum }
            }
            Optimizer::Nesterov { momentum } => {
                crate::compression::Accumulation::Nesterov { momentum }
            }
        }
    }
}

/// Per-parameter optimizer state for the *dense* (uncompressed) path.
/// Compressed layers keep their velocity inside
/// [`crate::compression::ResidualState`] instead (momentum correction).
#[derive(Clone, Debug)]
pub struct DenseOptState {
    velocity: Option<Vec<f32>>,
}

impl DenseOptState {
    pub fn new(n: usize, opt: Optimizer) -> Self {
        let velocity = match opt {
            Optimizer::Sgd => None,
            _ => Some(vec![0.0; n]),
        };
        DenseOptState { velocity }
    }

    /// w -= lr * step(g) under the chosen optimizer.
    pub fn apply(&mut self, opt: Optimizer, w: &mut [f32], g: &[f32], lr: f32) {
        match opt {
            Optimizer::Sgd => axpy(w, -lr, g),
            Optimizer::Momentum { momentum } => {
                let v = self.velocity.as_mut().expect("velocity state");
                for i in 0..g.len() {
                    v[i] = momentum * v[i] + g[i];
                    w[i] -= lr * v[i];
                }
            }
            Optimizer::Nesterov { momentum } => {
                let v = self.velocity.as_mut().expect("velocity state");
                for i in 0..g.len() {
                    v[i] = momentum * v[i] + g[i];
                    w[i] -= lr * (momentum * v[i] + g[i]);
                }
            }
        }
    }

    /// The velocity buffer, if the optimizer keeps one — checkpointed by
    /// the elastic layer alongside the compressed layers' residuals.
    pub fn velocity(&self) -> Option<&[f32]> {
        self.velocity.as_deref()
    }

    /// Restore a checkpointed velocity buffer (no-op target for SGD,
    /// which keeps none — asserting instead would make dense-SGD layers
    /// unrestorable).
    pub fn load_velocity(&mut self, v: &[f32]) {
        if let Some(cur) = &mut self.velocity {
            assert_eq!(cur.len(), v.len(), "velocity length");
            cur.copy_from_slice(v);
        }
    }
}

/// Learning-rate schedule: constant, step decay, or decay-on-plateau
/// (the paper decays when validation loss stops improving).
#[derive(Clone, Debug)]
pub enum LrSchedule {
    Constant { lr: f32 },
    /// lr * factor^(floor(step / every))
    StepDecay { lr: f32, factor: f32, every: usize },
    /// multiply by factor whenever `report_plateau` is signaled
    Plateau { lr: f32, factor: f32 },
}

impl LrSchedule {
    pub fn lr_at(&self, step: usize) -> f32 {
        match self {
            LrSchedule::Constant { lr } => *lr,
            LrSchedule::StepDecay { lr, factor, every } => {
                lr * factor.powi((step / every) as i32)
            }
            LrSchedule::Plateau { lr, .. } => *lr,
        }
    }

    /// Signal a validation plateau (only meaningful for `Plateau`).
    pub fn report_plateau(&mut self) {
        if let LrSchedule::Plateau { lr, factor } = self {
            *lr *= *factor;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sgd_step() {
        let mut st = DenseOptState::new(2, Optimizer::Sgd);
        let mut w = vec![1.0f32, 1.0];
        st.apply(Optimizer::Sgd, &mut w, &[1.0, -2.0], 0.1);
        assert_eq!(w, vec![0.9, 1.2]);
    }

    #[test]
    fn momentum_accumulates_velocity() {
        let opt = Optimizer::Momentum { momentum: 0.9 };
        let mut st = DenseOptState::new(1, opt);
        let mut w = vec![0.0f32];
        st.apply(opt, &mut w, &[1.0], 1.0); // v=1, w=-1
        st.apply(opt, &mut w, &[1.0], 1.0); // v=1.9, w=-2.9
        assert!((w[0] + 2.9).abs() < 1e-6);
    }

    #[test]
    fn nesterov_lookahead() {
        let opt = Optimizer::Nesterov { momentum: 0.5 };
        let mut st = DenseOptState::new(1, opt);
        let mut w = vec![0.0f32];
        st.apply(opt, &mut w, &[1.0], 1.0); // v=1, w -= 0.5*1+1 = 1.5
        assert!((w[0] + 1.5).abs() < 1e-6);
    }

    #[test]
    fn momentum_correction_matches_delayed_dense_update() {
        // DGC momentum correction semantics: if nothing is transmitted at
        // step 1 and everything at step 2, the transmitted residual must
        // equal the *sum of the two dense momentum updates* — the
        // accumulated v₁ + v₂ a dense momentum-SGD would have applied.
        use crate::compression::{Accumulation, ResidualState};
        let opt = Optimizer::Momentum { momentum: 0.9 };
        let mut dense_w = vec![0.0f32; 4];
        let mut st = DenseOptState::new(4, opt);
        let mut res = ResidualState::new(4, Accumulation::Momentum { momentum: 0.9 });
        let grads = [[1.0f32, -1.0, 0.5, 2.0], [0.3, 0.6, -0.2, 1.0]];
        for g in &grads {
            st.apply(opt, &mut dense_w, g, 0.1);
            res.accumulate(g); // nothing transmitted yet
        }
        let mut comp_w = vec![0.0f32; 4];
        let sel = crate::compression::exact_topk(res.residual(), 4, None);
        for (&i, &v) in sel.sparse.indices.iter().zip(&sel.sparse.values) {
            comp_w[i as usize] -= 0.1 * v;
        }
        res.mask(&sel.sparse);
        for (a, b) in dense_w.iter().zip(&comp_w) {
            assert!((a - b).abs() < 1e-6, "{dense_w:?} vs {comp_w:?}");
        }
        assert!(res.residual().iter().all(|&v| v == 0.0));
        // momentum *factor masking*: the velocity buffer is cleared at the
        // transmitted indices too (Alg. 4 line 23)
        assert!(res.momentum_buf().iter().all(|&v| v == 0.0));
    }

    #[test]
    fn full_density_every_step_reduces_to_plain_sgd() {
        // with density 1 and factor masking every step, the momentum
        // buffers are cleared each iteration: DGC degrades to vanilla SGD
        // (why warm-up uses the *dense* optimizer path instead, §5.7)
        use crate::compression::{Accumulation, ResidualState};
        let mut res = ResidualState::new(2, Accumulation::Momentum { momentum: 0.9 });
        let mut w = vec![0.0f32; 2];
        let grads = [[1.0f32, -2.0], [0.5, 0.5], [1.0, 1.0]];
        for g in &grads {
            res.accumulate(g);
            let sel = crate::compression::exact_topk(res.residual(), 2, None);
            for (&i, &v) in sel.sparse.indices.iter().zip(&sel.sparse.values) {
                w[i as usize] -= 0.1 * v;
            }
            res.mask(&sel.sparse);
        }
        let sgd: Vec<f32> = (0..2)
            .map(|i| -0.1 * grads.iter().map(|g| g[i]).sum::<f32>())
            .collect();
        for (a, b) in w.iter().zip(&sgd) {
            assert!((a - b).abs() < 1e-6, "{w:?} vs {sgd:?}");
        }
    }

    #[test]
    fn lr_schedules() {
        assert_eq!(LrSchedule::Constant { lr: 0.1 }.lr_at(100), 0.1);
        let s = LrSchedule::StepDecay { lr: 1.0, factor: 0.5, every: 10 };
        assert_eq!(s.lr_at(0), 1.0);
        assert_eq!(s.lr_at(10), 0.5);
        assert_eq!(s.lr_at(25), 0.25);
        let mut p = LrSchedule::Plateau { lr: 1.0, factor: 0.1 };
        p.report_plateau();
        assert!((p.lr_at(0) - 0.1).abs() < 1e-7);
    }
}
