//! Gradient clipping (§5.6).
//!
//! Dense data parallelism clips the *aggregated* gradient by global norm.
//! Under RGC no aggregated gradient exists before synchronization, so the
//! paper adopts DGC's *local clipping*: each worker clips its local
//! gradient with the threshold scaled by N^{-1/2} before accumulating
//! into the residual.

use crate::tensor::l2_norm;

/// Global-norm clip across a set of gradient buffers; returns the scale
/// factor applied (1.0 when under the threshold).
pub fn clip_by_global_norm(grads: &mut [&mut [f32]], max_norm: f32) -> f32 {
    let total: f64 = grads
        .iter()
        .map(|g| {
            let n = l2_norm(g) as f64;
            n * n
        })
        .sum();
    let norm = total.sqrt() as f32;
    if norm <= max_norm || norm == 0.0 {
        return 1.0;
    }
    let scale = max_norm / norm;
    for g in grads.iter_mut() {
        for v in g.iter_mut() {
            *v *= scale;
        }
    }
    scale
}

/// DGC local clipping threshold: `max_norm · N^{-1/2}` for N workers.
pub fn local_clip_factor(max_norm: f32, n_workers: usize) -> f32 {
    max_norm / (n_workers as f32).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn no_clip_under_threshold() {
        let mut a = vec![0.3f32, 0.4]; // norm 0.5
        let scale = clip_by_global_norm(&mut [&mut a], 1.0);
        assert_eq!(scale, 1.0);
        assert_eq!(a, vec![0.3, 0.4]);
    }

    #[test]
    fn clips_to_max_norm() {
        let mut a = vec![3.0f32];
        let mut b = vec![4.0f32]; // global norm 5
        let scale = clip_by_global_norm(&mut [&mut a, &mut b], 1.0);
        assert!((scale - 0.2).abs() < 1e-6);
        let norm = ((a[0] * a[0] + b[0] * b[0]) as f64).sqrt();
        assert!((norm - 1.0).abs() < 1e-6);
    }

    #[test]
    fn zero_gradient_safe() {
        let mut a = vec![0.0f32; 4];
        assert_eq!(clip_by_global_norm(&mut [&mut a], 1.0), 1.0);
    }

    #[test]
    fn local_factor_scaling() {
        assert_eq!(local_clip_factor(1.0, 1), 1.0);
        assert!((local_clip_factor(1.0, 4) - 0.5).abs() < 1e-7);
        assert!((local_clip_factor(2.0, 16) - 0.5).abs() < 1e-7);
    }

    #[test]
    fn local_clipping_bounds_aggregate() {
        // N workers each clipped to max/sqrt(N): aggregate mean norm is
        // bounded by max (triangle inequality / sqrt concentration)
        let n = 4usize;
        let thr = local_clip_factor(1.0, n);
        let mut agg = vec![0.0f32; 8];
        for w in 0..n {
            let mut g: Vec<f32> = (0..8).map(|i| (w + i) as f32).collect();
            clip_by_global_norm(&mut [&mut g], thr);
            for (a, v) in agg.iter_mut().zip(&g) {
                *a += v / n as f32;
            }
        }
        assert!(l2_norm(&agg) <= 1.0 + 1e-5);
    }
}
