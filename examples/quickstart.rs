//! Quickstart: train a tiny transformer LM with residual gradient
//! compression on 2 in-process workers, then compare against the dense
//! baseline.
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```

use redsync::config::{preset, TrainConfig};
use redsync::coordinator::train;
use redsync::simnet::iteration::Strategy;

fn main() {
    // start from the smoke preset: lm_tiny, 2 workers, 20 steps
    let mut cfg: TrainConfig = preset("smoke").expect("smoke preset");
    cfg.steps = 40;
    cfg.eval_every = 10;

    println!("== RGC (top-{:.1}% residuals, sparse allgather) ==", cfg.density * 100.0);
    let rgc = train(cfg.clone()).expect("RGC run");
    print!("{}", rgc.summary());

    println!("\n== dense baseline (allreduce every layer) ==");
    cfg.strategy = Strategy::Dense;
    let dense = train(cfg).expect("dense run");
    print!("{}", dense.summary());

    println!(
        "\ntraffic reduction: {:.1}x  ({} -> {})",
        dense.bytes as f64 / rgc.bytes as f64,
        redsync::util::fmt_bytes(dense.bytes as usize),
        redsync::util::fmt_bytes(rgc.bytes as usize),
    );
    assert!(rgc.replicas_consistent && dense.replicas_consistent);
    println!("replicas consistent on both runs — done.");
}
