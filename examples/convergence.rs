//! Convergence comparison (paper Fig. 6 / Tables 1-2 proxy): train the
//! same model under SGD (dense), RGC, and quantized RGC, and compare the
//! final quality — the paper's claim is that all three converge alike.
//!
//! ```sh
//! cargo run --release --example convergence            # mlp proxy
//! cargo run --release --example convergence -- --task lm
//! ```

use redsync::config::{preset, TrainConfig};
use redsync::coordinator::train;
use redsync::simnet::iteration::Strategy;
use redsync::util::argparse::Args;

fn run(mut cfg: TrainConfig, strategy: Strategy) -> (String, f32, f32, u64) {
    cfg.strategy = strategy;
    let r = train(cfg).expect("run");
    assert!(r.replicas_consistent);
    (
        strategy.label().to_string(),
        r.final_loss,
        r.final_eval.unwrap_or(f32::NAN),
        r.bytes,
    )
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::new("convergence", "SGD vs RGC vs quant-RGC convergence")
        .opt("task", "mlp", "mlp (accuracy) or lm (perplexity proxy)")
        .opt("steps", "", "override step count");
    let parsed = args.parse(&argv).unwrap_or_else(|e| {
        eprintln!("{e}");
        std::process::exit(2);
    });

    let mut cfg = if parsed.get("task") == "lm" {
        preset("fig6-lm").expect("preset")
    } else {
        preset("fig6-mlp").expect("preset")
    };
    if !parsed.get("steps").is_empty() {
        cfg.steps = parsed.usize("steps");
    }

    println!(
        "task {} ({} x{} workers, {} steps, density {})",
        parsed.get("task"),
        cfg.model,
        cfg.world,
        cfg.steps,
        cfg.density
    );
    let metric_name = if parsed.get("task") == "lm" { "held-out loss" } else { "accuracy" };
    println!("{:>10} {:>12} {:>14} {:>12}", "strategy", "final loss", metric_name, "traffic");

    let mut rows = Vec::new();
    for s in [Strategy::Dense, Strategy::Rgc, Strategy::QuantRgc] {
        let (label, loss, eval, bytes) = run(cfg.clone(), s);
        println!(
            "{label:>10} {loss:>12.4} {eval:>14.4} {:>12}",
            redsync::util::fmt_bytes(bytes as usize)
        );
        rows.push((label, loss, eval, bytes));
    }

    // the paper's claim: RGC quality within noise of SGD
    let sgd_eval = rows[0].2;
    for (label, _, eval, _) in &rows[1..] {
        let delta = (eval - sgd_eval).abs();
        println!("  {label} vs SGD: |Δ {metric_name}| = {delta:.4}");
    }
    println!(
        "  traffic: RGC {:.1}x less, quant-RGC {:.1}x less than dense",
        rows[0].3 as f64 / rows[1].3 as f64,
        rows[0].3 as f64 / rows[2].3 as f64
    );
}
