//! Scalability study (paper Figs. 7-9): virtual-time speedup curves for
//! the paper's DNN zoo on both machine presets, under dense / RGC /
//! quantized-RGC synchronization.
//!
//! ```sh
//! cargo run --release --example scalability
//! ```

use redsync::models::zoo;
use redsync::simnet::iteration::{speedup, SimConfig, Strategy};
use redsync::simnet::Machine;

fn sweep(machine: &Machine, models: &[&str], gpus: &[usize], cfg: &SimConfig) {
    for name in models {
        let model = zoo::by_name(name).expect("profile");
        println!("\n## {} on {} (weak scaling, batch/gpu {})", model.name, machine.name, cfg.batch_per_gpu);
        println!("{:>5} {:>10} {:>10} {:>10} {:>8} {:>8}", "gpus", "baseline", "RGC", "quantRGC", "R/base", "Q/base");
        for &p in gpus {
            let d = speedup(&model, machine, p, Strategy::Dense, cfg);
            let r = speedup(&model, machine, p, Strategy::Rgc, cfg);
            let q = speedup(&model, machine, p, Strategy::QuantRgc, cfg);
            println!("{p:>5} {d:>10.2} {r:>10.2} {q:>10.2} {:>8.2} {:>8.2}", r / d, q / d);
        }
    }
}

fn main() {
    let cfg = SimConfig::default();

    // Fig. 7: Piz Daint, up to 128 GPUs, ImageNet CNNs + PTB LSTM
    println!("# Fig. 7 — Piz Daint (1.5 GB/s Aries, 1 P100/node)");
    sweep(
        &Machine::piz_daint(),
        &["alexnet", "vgg16", "resnet50", "lstm-ptb"],
        &[2, 4, 8, 16, 32, 64, 128],
        &cfg,
    );

    // Fig. 8: Muradin, 8 GPUs, ImageNet CNNs
    println!("\n# Fig. 8 — Muradin (8x Titan V, 3.5 GB/s PCIe)");
    sweep(
        &Machine::muradin(),
        &["alexnet", "vgg16", "resnet50"],
        &[2, 4, 8],
        &cfg,
    );

    // Fig. 9: Muradin, LSTMs + VGG16-Cifar
    println!("\n# Fig. 9 — Muradin, LSTM PTB/Wiki2 + VGG16 on Cifar10");
    sweep(
        &Machine::muradin(),
        &["lstm-ptb", "lstm-wiki2", "vgg16-cifar"],
        &[2, 4, 8],
        &cfg,
    );

    println!(
        "\npaper shape checks: AlexNet/VGG/LSTM gain from RGC at scale, quant > plain \
         for CNNs, ResNet50 gains nothing (high compute/comm ratio)."
    );
}
