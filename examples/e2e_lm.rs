//! End-to-end driver: full-stack training of a transformer language model
//! on a synthetic Zipf-Markov corpus through all three layers —
//! Pallas-kernel selection artifacts (L1), the jax train-step HLO (L2)
//! executed via PJRT, and the Rust RGC coordinator (L3).
//!
//! Defaults to `lm_base` (~5.5M params) for a few hundred steps with
//! warm-up, momentum correction and local clipping — the configuration of
//! EXPERIMENTS.md §E2E.  Use `--model lm_med` / `--steps N` to scale up
//! (build bigger artifacts with `python -m compile.aot --full`).
//!
//! ```sh
//! make artifacts && cargo run --release --example e2e_lm -- --steps 300
//! ```

use redsync::config::{preset, TrainConfig};
use redsync::coordinator::train;
use redsync::simnet::iteration::Strategy;
use redsync::util::argparse::Args;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::new("e2e_lm", "end-to-end LM training driver")
        .opt("model", "lm_base", "artifact model (lm_tiny/lm_small/lm_base/lm_med)")
        .opt("steps", "300", "optimizer steps")
        .opt("world", "4", "workers (power of two)")
        .opt("density", "0.001", "compression density D")
        .opt("strategy", "rgc", "dense|rgc|quant")
        .opt("out", "", "write the loss curve as CSV to this path");
    let parsed = args.parse(&argv).unwrap_or_else(|e| {
        eprintln!("{e}");
        std::process::exit(2);
    });

    let mut cfg: TrainConfig = preset("e2e-lm").expect("preset");
    cfg.model = parsed.get("model").to_string();
    cfg.steps = parsed.usize("steps");
    cfg.world = parsed.usize("world");
    cfg.density = parsed.f64("density");
    cfg.strategy = match parsed.get("strategy") {
        "dense" => Strategy::Dense,
        "quant" => Strategy::QuantRgc,
        _ => Strategy::Rgc,
    };
    cfg.eval_every = (cfg.steps / 10).max(1);
    cfg.log_every = (cfg.steps / 50).max(1);

    println!(
        "e2e: {} x{} [{}] density {} for {} steps",
        cfg.model,
        cfg.world,
        cfg.strategy.label(),
        cfg.density,
        cfg.steps
    );
    let report = train(cfg).unwrap_or_else(|e| {
        eprintln!("run failed: {e}");
        std::process::exit(1);
    });

    println!("\nloss curve (step, global mean train loss):");
    for &(s, l) in &report.loss_curve {
        println!("  {s:>6}  {l:.4}");
    }
    println!("\neval curve (step, held-out loss):");
    for &(s, l) in &report.eval_curve {
        println!("  {s:>6}  {l:.4}");
    }
    print!("\n{}", report.summary());

    if !parsed.get("out").is_empty() {
        let mut csv = String::from("step,train_loss\n");
        for &(s, l) in &report.loss_curve {
            csv.push_str(&format!("{s},{l}\n"));
        }
        std::fs::write(parsed.get("out"), csv).expect("write csv");
        println!("wrote {}", parsed.get("out"));
    }

    // the run is only a success if training actually worked
    assert!(report.replicas_consistent, "replica divergence");
    let first = report.loss_curve.first().unwrap().1;
    let last = report.loss_curve.last().unwrap().1;
    assert!(last < first, "no learning: {first} -> {last}");
    println!("\nOK: loss {first:.3} -> {last:.3}, replicas consistent");
}
