"""Build-time compile path for RedSync: L2 jax models + L1 Pallas kernels,
AOT-lowered to HLO text artifacts consumed by the Rust coordinator.

Nothing in this package is imported at runtime; see DESIGN.md.
"""
