"""AOT compiler: lower every L2/L1 entry point to HLO *text* artifacts.

Run once at build time (``make artifacts``); the Rust coordinator loads the
text via ``HloModuleProto::from_text_file`` and executes through PJRT.

HLO text — not ``lowered.compile().serialize()`` and not a serialized
HloModuleProto — is the interchange format: jax >= 0.5 emits protos with
64-bit instruction ids which xla_extension 0.5.1 (the version behind the
published ``xla`` 0.1.6 crate) rejects (``proto.id() <= INT_MAX``).  The
text parser reassigns ids and round-trips cleanly.

Artifacts are incremental: a source-tree hash is stored in the manifest
and everything is skipped when unchanged (``--force`` overrides).
"""

import argparse
import hashlib
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from . import kernels as K

# Fusion-bucket sizes for the compression operators.  The Rust coordinator
# pads each layer's residual up to the next bucket (mirroring the paper's
# tensor fusion) so the artifact count stays bounded.
BUCKETS = [1024, 16384, 65536, 262144, 1048576, 4194304]

DEFAULT_MODELS = ["lm_tiny", "lm_small", "lm_base", "mlp_tiny", "mlp_small", "mlp_wide"]
FULL_MODELS = DEFAULT_MODELS + ["lm_med", "lm_100m"]

_DTYPES = {"f32": jnp.float32, "i32": jnp.int32}


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _source_hash() -> str:
    here = os.path.dirname(os.path.abspath(__file__))
    h = hashlib.sha256()
    for root, _, files in sorted(os.walk(here)):
        if "__pycache__" in root:
            continue
        for f in sorted(files):
            if f.endswith(".py"):
                with open(os.path.join(root, f), "rb") as fh:
                    h.update(fh.read())
    return h.hexdigest()[:16]


def lower_model(name, out_dir):
    """Lower one model's train-step (and eval) functions; return manifest entry."""
    if name.startswith("lm"):
        cfg = M.LM_CONFIGS[name]
        specs, in_specs = M.lm_param_specs(cfg), M.lm_input_specs(cfg)
        step, evalf = M.lm_step_fn(cfg), M.lm_logits_loss_fn(cfg)
        kind = "lm"
    else:
        cfg = M.MLP_CONFIGS[name]
        specs, in_specs = M.mlp_param_specs(cfg), M.mlp_input_specs(cfg)
        step, evalf = M.mlp_step_fn(cfg), M.mlp_logits_fn(cfg)
        kind = "mlp"

    args = [_spec(shape) for _, shape, _ in specs]
    args += [_spec(shape, _DTYPES[dt]) for _, shape, dt in in_specs]

    t0 = time.time()
    step_file = f"{name}.hlo.txt"
    with open(os.path.join(out_dir, step_file), "w") as f:
        f.write(to_hlo_text(jax.jit(step).lower(*args)))

    eval_args = args if kind == "lm" else args[: len(specs)] + [args[len(specs)]]
    eval_file = f"{name}_eval.hlo.txt"
    with open(os.path.join(out_dir, eval_file), "w") as f:
        f.write(to_hlo_text(jax.jit(evalf).lower(*eval_args)))
    dt = time.time() - t0

    n_params = M.param_count(specs)
    print(f"  {name}: {n_params:,} params, lowered in {dt:.1f}s", flush=True)
    return {
        "kind": kind,
        "file": step_file,
        "eval_file": eval_file,
        "config": cfg,
        "param_count": n_params,
        "params": [
            {"name": n, "shape": list(s), "init": init}
            for n, s, init in specs
        ],
        "inputs": [
            {"name": n, "shape": list(s), "dtype": dt_}
            for n, s, dt_ in in_specs
        ],
        # step outputs: loss f32[1] followed by one grad per param, in order
        "outputs": ["loss"] + [n for n, _, _ in specs],
    }


def lower_compress_ops(out_dir):
    """Lower the per-bucket compression kernels; return manifest entries."""
    ops = {}
    j = K.NUM_THRESHOLDS
    for n in BUCKETS:
        x = _spec((n,))
        one = _spec((1,))
        files = {
            "abs_stats": (K.abs_stats, [x]),
            "threshold_count": (K.threshold_count, [x, _spec((j,))]),
            "compress_mask": (K.compress_mask, [x, one, one]),
            "sgd_update": (K.sgd_update, [x, x, one]),
            "momentum_accum": (K.momentum_accum, [x, x, x, one, one]),
        }
        t0 = time.time()
        for opname, (fn, specs) in files.items():
            fname = f"{opname}_{n}.hlo.txt"
            with open(os.path.join(out_dir, fname), "w") as f:
                f.write(to_hlo_text(jax.jit(fn).lower(*specs)))
            ops.setdefault(opname, {"buckets": {}})["buckets"][str(n)] = fname
        print(f"  compress ops @ {n}: lowered in {time.time()-t0:.1f}s", flush=True)
    ops["threshold_count"]["num_thresholds"] = j
    return ops


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default=os.path.join(os.path.dirname(__file__), "..", "..", "artifacts"))
    ap.add_argument("--full", action="store_true", help="also build lm_med / lm_100m")
    ap.add_argument("--force", action="store_true", help="rebuild even if unchanged")
    ap.add_argument("--models", nargs="*", help="explicit model list override")
    args = ap.parse_args()

    out_dir = os.path.abspath(args.out_dir)
    os.makedirs(out_dir, exist_ok=True)
    manifest_path = os.path.join(out_dir, "manifest.json")

    models = args.models or (FULL_MODELS if args.full else DEFAULT_MODELS)
    src_hash = _source_hash()

    if not args.force and os.path.exists(manifest_path):
        with open(manifest_path) as f:
            old = json.load(f)
        have = set(old.get("models", {}))
        if old.get("source_hash") == src_hash and set(models) <= have:
            ok = all(
                os.path.exists(os.path.join(out_dir, e["file"]))
                for e in old["models"].values()
            )
            if ok:
                print(f"artifacts up to date (hash {src_hash}); skipping")
                return

    print(f"lowering artifacts -> {out_dir} (source hash {src_hash})", flush=True)
    manifest = {
        "source_hash": src_hash,
        "jax_version": jax.__version__,
        "buckets": BUCKETS,
        "models": {},
        "compress_ops": {},
    }
    print("models:", flush=True)
    for name in models:
        manifest["models"][name] = lower_model(name, out_dir)
    print("compression operators:", flush=True)
    manifest["compress_ops"] = lower_compress_ops(out_dir)

    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {manifest_path}")


if __name__ == "__main__":
    sys.exit(main())
