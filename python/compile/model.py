"""L2 jax models: the workloads RedSync trains.

Each model exposes
  - ``*_configs``      named size presets
  - ``*_param_specs``  ordered (name, shape, init) list — the contract the
                       Rust coordinator uses to allocate/initialize params
  - ``*_step_fn``      pure fn(*params, inputs...) -> (loss, *grads) that
                       aot.py lowers to a single HLO artifact

The step functions are *stateless*: the optimizer, residual-gradient
compression, synchronization and the weight update all live in the Rust
L3 coordinator (that separation is the paper's system boundary — gradients
come off the device, everything after is RedSync).

The transformer MLP block routes through the Pallas ``fused_gelu`` kernel
so an L1 kernel lowers into the model HLO as well as the compression ops.
"""

import functools

import jax
import jax.numpy as jnp

from .kernels import fused_gelu

# --------------------------------------------------------------------------
# Transformer language model (decoder-only, pre-LN, learned positions)
# --------------------------------------------------------------------------

LM_CONFIGS = {
    # unit tests / CI
    "lm_tiny": dict(vocab=64, d_model=32, n_layers=1, n_heads=2, seq=16, batch=4),
    # convergence experiments (thousands of steps feasible on 1 CPU core)
    "lm_small": dict(vocab=512, d_model=128, n_layers=2, n_heads=4, seq=32, batch=8),
    # e2e driver default (~5.5M params)
    "lm_base": dict(vocab=4096, d_model=256, n_layers=4, n_heads=8, seq=64, batch=8),
    # mid-scale e2e (~27M params)
    "lm_med": dict(vocab=8192, d_model=512, n_layers=6, n_heads=8, seq=64, batch=4),
    # 100M-class config (built with --full; see EXPERIMENTS.md for what the
    # 1-core testbed can actually step through)
    "lm_100m": dict(vocab=32768, d_model=768, n_layers=8, n_heads=12, seq=128, batch=4),
}


def lm_param_specs(cfg):
    """Ordered parameter contract: (name, shape, init-spec)."""
    v, d, l = cfg["vocab"], cfg["d_model"], cfg["n_layers"]
    h = 4 * d
    specs = [
        ("embed", (v, d), {"kind": "normal", "std": 0.02}),
        ("pos", (cfg["seq"], d), {"kind": "normal", "std": 0.01}),
    ]
    for i in range(l):
        p = f"layer{i}."
        specs += [
            (p + "ln1.scale", (d,), {"kind": "ones"}),
            (p + "ln1.bias", (d,), {"kind": "zeros"}),
            (p + "attn.wq", (d, d), {"kind": "normal", "std": 0.02}),
            (p + "attn.wk", (d, d), {"kind": "normal", "std": 0.02}),
            (p + "attn.wv", (d, d), {"kind": "normal", "std": 0.02}),
            (p + "attn.wo", (d, d), {"kind": "residual", "std": 0.02, "layers": l}),
            (p + "ln2.scale", (d,), {"kind": "ones"}),
            (p + "ln2.bias", (d,), {"kind": "zeros"}),
            (p + "mlp.w1", (d, h), {"kind": "normal", "std": 0.02}),
            (p + "mlp.b1", (h,), {"kind": "zeros"}),
            (p + "mlp.w2", (h, d), {"kind": "residual", "std": 0.02, "layers": l}),
            (p + "mlp.b2", (d,), {"kind": "zeros"}),
        ]
    specs += [
        ("ln_f.scale", (d,), {"kind": "ones"}),
        ("ln_f.bias", (d,), {"kind": "zeros"}),
        ("head", (d, v), {"kind": "normal", "std": 0.02}),
    ]
    return specs


def _layer_norm(x, scale, bias, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * scale + bias


def _attention(x, wq, wk, wv, wo, n_heads):
    b, s, d = x.shape
    hd = d // n_heads

    def split(t):
        return t.reshape(b, s, n_heads, hd).transpose(0, 2, 1, 3)

    q, k, v = split(x @ wq), split(x @ wk), split(x @ wv)
    att = (q @ k.transpose(0, 1, 3, 2)) / jnp.sqrt(float(hd))
    causal = jnp.tril(jnp.ones((s, s), dtype=bool))
    att = jnp.where(causal[None, None], att, -1e30)
    att = jax.nn.softmax(att, axis=-1)
    out = (att @ v).transpose(0, 2, 1, 3).reshape(b, s, d)
    return out @ wo


def lm_loss(params, tokens, targets, cfg):
    """Mean token cross-entropy of the decoder-only LM."""
    specs = lm_param_specs(cfg)
    p = {name: arr for (name, _, _), arr in zip(specs, params)}
    l, nh = cfg["n_layers"], cfg["n_heads"]

    x = p["embed"][tokens] + p["pos"][None, : tokens.shape[1]]
    for i in range(l):
        pre = f"layer{i}."
        h = _layer_norm(x, p[pre + "ln1.scale"], p[pre + "ln1.bias"])
        x = x + _attention(
            h, p[pre + "attn.wq"], p[pre + "attn.wk"], p[pre + "attn.wv"],
            p[pre + "attn.wo"], nh,
        )
        h = _layer_norm(x, p[pre + "ln2.scale"], p[pre + "ln2.bias"])
        h = fused_gelu(h @ p[pre + "mlp.w1"] + p[pre + "mlp.b1"])
        x = x + h @ p[pre + "mlp.w2"] + p[pre + "mlp.b2"]
    x = _layer_norm(x, p["ln_f.scale"], p["ln_f.bias"])
    logits = x @ p["head"]

    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


def lm_step_fn(cfg):
    """fn(*params, tokens, targets) -> (loss, *grads), lowered by aot.py."""
    n_params = len(lm_param_specs(cfg))

    def step(*args):
        params = list(args[:n_params])
        tokens, targets = args[n_params], args[n_params + 1]
        loss, grads = jax.value_and_grad(
            lambda ps: lm_loss(ps, tokens, targets, cfg)
        )(params)
        return (loss.reshape((1,)), *grads)

    return step


def lm_input_specs(cfg):
    b, s = cfg["batch"], cfg["seq"]
    return [
        ("tokens", (b, s), "i32"),
        ("targets", (b, s), "i32"),
    ]


# --------------------------------------------------------------------------
# MLP classifier — the fast proxy for the accuracy experiments (Fig 6,
# Tables 1-2): thousands of optimizer steps per second on one core.
# --------------------------------------------------------------------------

MLP_CONFIGS = {
    "mlp_tiny": dict(in_dim=16, hidden=32, depth=1, classes=4, batch=16),
    "mlp_small": dict(in_dim=64, hidden=256, depth=2, classes=10, batch=64),
    # wide variant: one large fc layer dominating the message-size mix the
    # way VGG16's fc6 does — exercises the binary-search policy branch.
    "mlp_wide": dict(in_dim=64, hidden=1024, depth=2, classes=10, batch=64),
}


def mlp_param_specs(cfg):
    dims = [cfg["in_dim"]] + [cfg["hidden"]] * cfg["depth"] + [cfg["classes"]]
    specs = []
    for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
        specs.append((f"fc{i}.w", (a, b), {"kind": "he", "fan_in": a}))
        specs.append((f"fc{i}.b", (b,), {"kind": "zeros"}))
    return specs


def mlp_loss(params, x, y, cfg):
    n_fc = cfg["depth"] + 1
    h = x
    for i in range(n_fc):
        w, b = params[2 * i], params[2 * i + 1]
        h = h @ w + b
        if i < n_fc - 1:
            h = fused_gelu(h)
    logz = jax.scipy.special.logsumexp(h, axis=-1)
    gold = jnp.take_along_axis(h, y[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


def mlp_step_fn(cfg):
    n_params = len(mlp_param_specs(cfg))

    def step(*args):
        params = list(args[:n_params])
        x, y = args[n_params], args[n_params + 1]
        loss, grads = jax.value_and_grad(
            lambda ps: mlp_loss(ps, x, y, cfg)
        )(params)
        return (loss.reshape((1,)), *grads)

    return step


def mlp_input_specs(cfg):
    return [
        ("x", (cfg["batch"], cfg["in_dim"]), "f32"),
        ("y", (cfg["batch"],), "i32"),
    ]


# --------------------------------------------------------------------------
# Inference helpers (accuracy eval artifacts): logits only, no grads.
# --------------------------------------------------------------------------

def mlp_logits_fn(cfg):
    n_params = len(mlp_param_specs(cfg))
    n_fc = cfg["depth"] + 1

    def fwd(*args):
        params = list(args[:n_params])
        x = args[n_params]
        h = x
        for i in range(n_fc):
            w, b = params[2 * i], params[2 * i + 1]
            h = h @ w + b
            if i < n_fc - 1:
                h = fused_gelu(h)
        return (h,)

    return fwd


def lm_logits_loss_fn(cfg):
    """Eval-only artifact: (loss,) on a held-out batch."""
    n_params = len(lm_param_specs(cfg))

    def fwd(*args):
        params = list(args[:n_params])
        tokens, targets = args[n_params], args[n_params + 1]
        return (lm_loss(params, tokens, targets, cfg).reshape((1,)),)

    return fwd


def param_count(specs):
    n = 0
    for _, shape, _ in specs:
        size = 1
        for s in shape:
            size *= s
        n += size
    return n


@functools.lru_cache(maxsize=None)
def summary():
    lines = []
    for name, cfg in LM_CONFIGS.items():
        lines.append(f"{name}: {param_count(lm_param_specs(cfg)):,} params")
    for name, cfg in MLP_CONFIGS.items():
        lines.append(f"{name}: {param_count(mlp_param_specs(cfg)):,} params")
    return "\n".join(lines)


if __name__ == "__main__":
    print(summary())
