"""L1 Pallas kernels (selection hot-spot + model-side fused ops)."""

from .selection import (  # noqa: F401
    DEFAULT_BLOCK,
    NUM_THRESHOLDS,
    abs_stats,
    compress_mask,
    fused_gelu,
    momentum_accum,
    sgd_update,
    threshold_count,
)
