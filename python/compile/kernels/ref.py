"""Pure-jnp correctness oracles for every L1 Pallas kernel.

These define the semantics; ``python/tests/test_kernels.py`` sweeps shapes
and dtypes with hypothesis and asserts allclose kernel-vs-oracle.
"""

import jax.numpy as jnp


def abs_stats_ref(x):
    a = jnp.abs(x)
    return jnp.sum(a).reshape((1,)), jnp.max(a).reshape((1,))


def threshold_count_ref(x, thresholds):
    a = jnp.abs(x)
    return jnp.sum(
        (a[None, :] > thresholds[:, None]).astype(jnp.float32), axis=1
    )


def compress_mask_ref(x, threshold, sign_mode):
    s = sign_mode[0]
    thr = threshold[0]
    key = jnp.where(s == 0.0, jnp.abs(x), s * x)
    mask = (key > thr).astype(jnp.float32)
    residual = x * (1.0 - mask)
    sel_sum = jnp.sum(x * mask).reshape((1,))
    sel_cnt = jnp.sum(mask).reshape((1,))
    return mask, residual, sel_sum, sel_cnt


def sgd_update_ref(w, g, lr):
    return w - lr[0] * g


def gelu_ref(x):
    c = 0.7978845608028654
    return 0.5 * x * (1.0 + jnp.tanh(c * (x + 0.044715 * x**3)))


def momentum_accum_ref(v, u, g, momentum, nesterov):
    """Fused Alg. 4 momentum-correction accumulation (lines 11-19):
    u' = m*u + g;  v' = v + u' + nesterov*g.  momentum=0 and nesterov=0
    reduce to plain SGD accumulation v += g."""
    un = momentum[0] * u + g
    return v + un + nesterov[0] * g, un
