"""L1 Pallas kernels for RedSync's compute hot-spot: communication-set selection.

The paper implements selection on GPU with prefix-sum primitives
(radixSelect, count_nonzero, stream compaction).  On the TPU model these
become *grid reductions over VMEM tiles* (see DESIGN.md
§Hardware-Adaptation):

 - ``abs_stats``       one HBM pass -> (sum |x|, max |x|)           (Alg. 2/3 lines 1-2)
 - ``threshold_count`` one HBM pass -> counts of |x| > t_j for a
                        whole *vector* of J candidate thresholds —
                        a J-way-parallel binary-search step
 - ``compress_mask``   one fused HBM pass -> selection mask, residual
                        update V*(1-mask), and sign-partitioned sums for
                        the quantization mean                        (Alg. 1 l.7-9, §5.2.3)
 - ``sgd_update``      fused dense w -= lr*g over a fusion bucket

All kernels run with ``interpret=True``: the CPU PJRT plugin cannot execute
Mosaic custom-calls, so the interpret lowering (plain HLO) is the
correctness path; TPU efficiency is estimated from the BlockSpec VMEM
footprint in DESIGN.md §Perf.

Every kernel has a pure-jnp oracle in ``ref.py``; pytest + hypothesis
assert allclose over shape/dtype sweeps.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# 64 Ki f32 = 256 KiB per input tile: comfortably inside a 16 MiB VMEM
# budget with double-buffering headroom, and 128-lane aligned.
DEFAULT_BLOCK = 65536

# Number of simultaneous binary-search probes serviced by one HBM pass.
NUM_THRESHOLDS = 16


def _block_for(n: int) -> int:
    """Largest power-of-two tile <= DEFAULT_BLOCK that divides n.

    Bucket sizes are powers of two (>= 2^10), so this always terminates
    with an aligned tile.
    """
    b = min(n, DEFAULT_BLOCK)
    while n % b != 0:
        b //= 2
    return b


def abs_stats(x):
    """Single-pass (sum(|x|), max(|x|)) over a 1-D tensor.

    Returns two f32[1] arrays.  mean = sum / n is computed by the caller
    (the Rust coordinator), keeping the kernel shape-agnostic.
    """
    n = x.shape[0]
    b = _block_for(n)
    grid = n // b

    def kernel(x_ref, sum_ref, max_ref):
        i = pl.program_id(0)

        @pl.when(i == 0)
        def _init():
            sum_ref[...] = jnp.zeros_like(sum_ref)
            max_ref[...] = jnp.zeros_like(max_ref)

        a = jnp.abs(x_ref[...])
        sum_ref[...] = sum_ref[...] + jnp.sum(a)
        max_ref[...] = jnp.maximum(max_ref[...], jnp.max(a))

    return pl.pallas_call(
        kernel,
        grid=(grid,),
        in_specs=[pl.BlockSpec((b,), lambda i: (i,))],
        out_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1,), jnp.float32),
            jax.ShapeDtypeStruct((1,), jnp.float32),
        ],
        interpret=True,
    )(x)


def threshold_count(x, thresholds):
    """Counts of |x| > t_j for each of J candidate thresholds, one pass.

    This is the TPU replacement for the paper's repeated
    ``count_nonzero(abs(X) > threshold)`` (Alg. 3 line 7): instead of one
    HBM sweep per probe, the (BLOCK,) tile is broadcast against the (J,)
    threshold vector resident in VMEM, so a 16-way bisection needs a
    single sweep.  Returns f32[J] counts.
    """
    n = x.shape[0]
    (j,) = thresholds.shape
    b = _block_for(n)
    grid = n // b

    def kernel(x_ref, t_ref, cnt_ref):
        i = pl.program_id(0)

        @pl.when(i == 0)
        def _init():
            cnt_ref[...] = jnp.zeros_like(cnt_ref)

        a = jnp.abs(x_ref[...])  # (b,)
        t = t_ref[...]  # (j,)
        # (j, b) broadcast compare; the VPU analog of warp-vote counting.
        c = jnp.sum((a[None, :] > t[:, None]).astype(jnp.float32), axis=1)
        cnt_ref[...] = cnt_ref[...] + c

    return pl.pallas_call(
        kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((b,), lambda i: (i,)),
            pl.BlockSpec((j,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((j,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((j,), jnp.float32),
        interpret=True,
    )(x, thresholds)


def compress_mask(x, threshold, sign_mode):
    """Fused selection pass (Alg. 1 lines 7-9 + quantization stats §5.2.3).

    ``sign_mode`` is an f32[1] runtime flag:
      0.0 -> magnitude selection:  key = |x|      (plain RGC)
     +1.0 -> top-k   selection:    key = +x       (quantized RGC, even iters)
     -1.0 -> bottom-k selection:   key = -x       (quantized RGC, odd iters)

    Returns (mask f32[n], residual f32[n], sel_sum f32[1], sel_cnt f32[1])
    where residual = x * (1 - mask) is the post-extraction residual the
    worker keeps, and sel_sum/sel_cnt give mean(selected) for the
    quantized message.  The host packs values it already holds; only the
    D*M-sized communication-set ever needs to leave the device.
    """
    n = x.shape[0]
    b = _block_for(n)
    grid = n // b

    def kernel(x_ref, t_ref, s_ref, mask_ref, res_ref, sum_ref, cnt_ref):
        i = pl.program_id(0)

        @pl.when(i == 0)
        def _init():
            sum_ref[...] = jnp.zeros_like(sum_ref)
            cnt_ref[...] = jnp.zeros_like(cnt_ref)

        v = x_ref[...]
        s = s_ref[0]
        thr = t_ref[0]
        key = jnp.where(s == 0.0, jnp.abs(v), s * v)
        m = (key > thr).astype(jnp.float32)
        mask_ref[...] = m
        res_ref[...] = v * (1.0 - m)
        sum_ref[...] = sum_ref[...] + jnp.sum(v * m)
        cnt_ref[...] = cnt_ref[...] + jnp.sum(m)

    return pl.pallas_call(
        kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((b,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((b,), lambda i: (i,)),
            pl.BlockSpec((b,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((1,), jnp.float32),
            jax.ShapeDtypeStruct((1,), jnp.float32),
        ],
        interpret=True,
    )(x, threshold, sign_mode)


def sgd_update(w, g, lr):
    """Fused dense SGD step over a (fusion-bucketed) parameter vector."""
    n = w.shape[0]
    b = _block_for(n)
    grid = n // b

    def kernel(w_ref, g_ref, lr_ref, o_ref):
        o_ref[...] = w_ref[...] - lr_ref[0] * g_ref[...]

    return pl.pallas_call(
        kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((b,), lambda i: (i,)),
            pl.BlockSpec((b,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((b,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(w, g, lr)


def momentum_accum(v, u, g, momentum, nesterov):
    """Fused momentum-correction accumulation (Alg. 4 lines 11-19).

    ``u' = momentum*u + g``; ``v' = v + u' + nesterov*g`` — the Fig. 10
    "mask"-phase arithmetic fused into one HBM pass over three streams
    (the GPU implementation needs three separate axpy launches).
    ``momentum = 0, nesterov = 0`` degrades to plain SGD accumulation.
    """
    n = v.shape[0]
    b = _block_for(n)
    grid = n // b

    def kernel(v_ref, u_ref, g_ref, m_ref, nv_ref, vo_ref, uo_ref):
        un = m_ref[0] * u_ref[...] + g_ref[...]
        uo_ref[...] = un
        vo_ref[...] = v_ref[...] + un + nv_ref[0] * g_ref[...]

    return pl.pallas_call(
        kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((b,), lambda i: (i,)),
            pl.BlockSpec((b,), lambda i: (i,)),
            pl.BlockSpec((b,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((b,), lambda i: (i,)),
            pl.BlockSpec((b,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
        ],
        interpret=True,
    )(v, u, g, momentum, nesterov)


@functools.partial(jax.custom_vjp)
def fused_gelu(x):
    """tanh-approx GELU as a Pallas elementwise kernel.

    Used inside the L2 transformer MLP block so that a Pallas kernel is
    exercised on the *model* path as well as the compression path.  The
    VJP is a closed-form jnp expression (interpret-mode pallas_call is not
    transposable in general), registered via custom_vjp so jax.grad
    composes.
    """
    return _gelu_fwd_kernel(x)


_SQRT_2_OVER_PI = 0.7978845608028654


def _gelu_fwd_kernel(x):
    flat = x.reshape((-1,))
    n = flat.shape[0]
    b = _block_for(n) if n >= 2 else n
    grid = max(n // b, 1)

    def kernel(x_ref, o_ref):
        v = x_ref[...]
        inner = _SQRT_2_OVER_PI * (v + 0.044715 * v * v * v)
        o_ref[...] = 0.5 * v * (1.0 + jnp.tanh(inner))

    out = pl.pallas_call(
        kernel,
        grid=(grid,),
        in_specs=[pl.BlockSpec((b,), lambda i: (i,))],
        out_specs=pl.BlockSpec((b,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(flat)
    return out.reshape(x.shape)


def _gelu_vjp_fwd(x):
    return fused_gelu(x), x


def _gelu_vjp_bwd(x, ct):
    inner = _SQRT_2_OVER_PI * (x + 0.044715 * x**3)
    t = jnp.tanh(inner)
    sech2 = 1.0 - t * t
    d_inner = _SQRT_2_OVER_PI * (1.0 + 3 * 0.044715 * x * x)
    grad = 0.5 * (1.0 + t) + 0.5 * x * sech2 * d_inner
    return (ct * grad,)


fused_gelu.defvjp(_gelu_vjp_fwd, _gelu_vjp_bwd)
