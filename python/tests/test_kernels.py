"""Kernel-vs-oracle correctness: the CORE L1 signal.

Each Pallas kernel (interpret mode) is checked against the pure-jnp oracle
in ``kernels/ref.py`` over hypothesis-driven shape/value sweeps.
"""

import sys, os

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import kernels as K
from compile.kernels import ref as R

SIZES = [128, 1024, 4096, 65536, 262144]


def rnd(n, seed=0, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(n).astype(np.float32) * scale)


# ---------------------------------------------------------------- abs_stats

@pytest.mark.parametrize("n", SIZES)
def test_abs_stats_matches_ref(n):
    x = rnd(n, seed=n)
    s, m = K.abs_stats(x)
    rs, rm = R.abs_stats_ref(x)
    np.testing.assert_allclose(s, rs, rtol=1e-5)
    np.testing.assert_allclose(m, rm, rtol=1e-6)


def test_abs_stats_all_negative():
    x = -jnp.abs(rnd(2048, seed=3)) - 0.5
    s, m = K.abs_stats(x)
    assert float(m[0]) > 0.5
    np.testing.assert_allclose(s, R.abs_stats_ref(x)[0], rtol=1e-5)


def test_abs_stats_zeros():
    x = jnp.zeros((1024,), jnp.float32)
    s, m = K.abs_stats(x)
    assert float(s[0]) == 0.0 and float(m[0]) == 0.0


@settings(max_examples=20, deadline=None)
@given(
    logn=st.integers(min_value=5, max_value=14),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    scale=st.floats(min_value=1e-3, max_value=1e3),
)
def test_abs_stats_hypothesis(logn, seed, scale):
    x = rnd(2**logn, seed=seed, scale=scale)
    s, m = K.abs_stats(x)
    rs, rm = R.abs_stats_ref(x)
    np.testing.assert_allclose(s, rs, rtol=2e-5)
    np.testing.assert_allclose(m, rm, rtol=1e-6)


# ---------------------------------------------------------- threshold_count

@pytest.mark.parametrize("n", SIZES)
def test_threshold_count_matches_ref(n):
    x = rnd(n, seed=n + 1)
    t = jnp.linspace(0.0, 3.0, K.NUM_THRESHOLDS).astype(jnp.float32)
    c = K.threshold_count(x, t)
    rc = R.threshold_count_ref(x, t)
    np.testing.assert_array_equal(np.asarray(c), np.asarray(rc))


def test_threshold_count_monotone_nonincreasing():
    x = rnd(65536, seed=7)
    t = jnp.linspace(0.0, 4.0, K.NUM_THRESHOLDS).astype(jnp.float32)
    c = np.asarray(K.threshold_count(x, t))
    assert (np.diff(c) <= 0).all(), "counts must not increase with threshold"


def test_threshold_count_zero_threshold_counts_nonzeros():
    x = jnp.concatenate([jnp.zeros((512,)), jnp.ones((512,))]).astype(jnp.float32)
    t = jnp.zeros((K.NUM_THRESHOLDS,), jnp.float32)
    c = np.asarray(K.threshold_count(x, t))
    assert (c == 512).all()


@settings(max_examples=15, deadline=None)
@given(
    logn=st.integers(min_value=7, max_value=14),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_threshold_count_hypothesis(logn, seed, ):
    x = rnd(2**logn, seed=seed)
    rng = np.random.default_rng(seed + 1)
    t = jnp.asarray(np.sort(rng.uniform(0, 3, K.NUM_THRESHOLDS)).astype(np.float32))
    np.testing.assert_array_equal(
        np.asarray(K.threshold_count(x, t)),
        np.asarray(R.threshold_count_ref(x, t)),
    )


# ------------------------------------------------------------ compress_mask

@pytest.mark.parametrize("n", SIZES)
@pytest.mark.parametrize("mode", [0.0, 1.0, -1.0])
def test_compress_mask_matches_ref(n, mode):
    x = rnd(n, seed=n + 17)
    thr = jnp.asarray([0.8], jnp.float32)
    s = jnp.asarray([mode], jnp.float32)
    out = K.compress_mask(x, thr, s)
    ref = R.compress_mask_ref(x, thr, s)
    for a, b in zip(out, ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6)


def test_compress_mask_residual_conservation():
    """mask*x + residual == x exactly (selection moves mass, never loses it)."""
    x = rnd(65536, seed=23)
    thr = jnp.asarray([0.5], jnp.float32)
    s = jnp.asarray([0.0], jnp.float32)
    mask, res, _, _ = K.compress_mask(x, thr, s)
    np.testing.assert_array_equal(
        np.asarray(mask * x + res), np.asarray(x)
    )


def test_compress_mask_sign_modes_partition():
    """top-k mode selects only positives, bottom-k only negatives."""
    x = rnd(4096, seed=5)
    thr = jnp.asarray([0.3], jnp.float32)
    mp, _, sp, cp = K.compress_mask(x, thr, jnp.asarray([1.0], jnp.float32))
    mn, _, sn, cn = K.compress_mask(x, thr, jnp.asarray([-1.0], jnp.float32))
    xs = np.asarray(x)
    assert (xs[np.asarray(mp) > 0] > 0).all()
    assert (xs[np.asarray(mn) > 0] < 0).all()
    assert float(sp[0]) > 0 and float(sn[0]) < 0
    # quant means have the right sign
    if float(cp[0]) > 0:
        assert float(sp[0]) / float(cp[0]) > float(thr[0])
    if float(cn[0]) > 0:
        assert float(sn[0]) / float(cn[0]) < -float(thr[0])


def test_compress_mask_huge_threshold_selects_nothing():
    x = rnd(1024, seed=9)
    thr = jnp.asarray([1e9], jnp.float32)
    mask, res, ssum, scnt = K.compress_mask(x, thr, jnp.asarray([0.0], jnp.float32))
    assert float(scnt[0]) == 0.0 and float(ssum[0]) == 0.0
    np.testing.assert_array_equal(np.asarray(res), np.asarray(x))


@settings(max_examples=15, deadline=None)
@given(
    logn=st.integers(min_value=7, max_value=13),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    thr=st.floats(min_value=0.0, max_value=3.0),
    mode=st.sampled_from([0.0, 1.0, -1.0]),
)
def test_compress_mask_hypothesis(logn, seed, thr, mode):
    x = rnd(2**logn, seed=seed)
    t = jnp.asarray([thr], jnp.float32)
    s = jnp.asarray([mode], jnp.float32)
    out = K.compress_mask(x, t, s)
    ref = R.compress_mask_ref(x, t, s)
    for a, b in zip(out, ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6)


# --------------------------------------------------------------- sgd_update

@pytest.mark.parametrize("n", SIZES)
def test_sgd_update_matches_ref(n):
    w, g = rnd(n, seed=1), rnd(n, seed=2)
    lr = jnp.asarray([0.05], jnp.float32)
    # rtol/atol: the pallas lowering fuses w - lr*g into an FMA while the
    # jnp oracle rounds the product first; near-cancellation elements differ
    # in the last ulp.
    np.testing.assert_allclose(
        np.asarray(K.sgd_update(w, g, lr)),
        np.asarray(R.sgd_update_ref(w, g, lr)),
        rtol=1e-5,
        atol=1e-6,
    )


def test_sgd_update_zero_lr_is_identity():
    w, g = rnd(2048, seed=4), rnd(2048, seed=6)
    out = K.sgd_update(w, g, jnp.asarray([0.0], jnp.float32))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(w))


# --------------------------------------------------------------- fused_gelu

@pytest.mark.parametrize("shape", [(128,), (8, 64), (4, 16, 32)])
def test_gelu_matches_ref(shape):
    rng = np.random.default_rng(11)
    x = jnp.asarray(rng.standard_normal(shape).astype(np.float32) * 2)
    np.testing.assert_allclose(
        np.asarray(K.fused_gelu(x)), np.asarray(R.gelu_ref(x)), rtol=1e-5, atol=1e-6
    )


def test_gelu_grad_matches_numeric():
    x = jnp.asarray(np.linspace(-3, 3, 64, dtype=np.float32))
    g = jax.grad(lambda v: jnp.sum(K.fused_gelu(v)))(x)
    eps = 1e-3
    num = (np.asarray(R.gelu_ref(x + eps)) - np.asarray(R.gelu_ref(x - eps))) / (2 * eps)
    np.testing.assert_allclose(np.asarray(g), num, rtol=2e-3, atol=2e-3)


# ----------------------------------------------------------- momentum_accum

@pytest.mark.parametrize("n", SIZES)
@pytest.mark.parametrize("m,nv", [(0.0, 0.0), (0.9, 0.0), (0.9, 1.0)])
def test_momentum_accum_matches_ref(n, m, nv):
    v, u, g = rnd(n, seed=21), rnd(n, seed=22), rnd(n, seed=23)
    mm = jnp.asarray([m], jnp.float32)
    nn = jnp.asarray([nv], jnp.float32)
    got_v, got_u = K.momentum_accum(v, u, g, mm, nn)
    ref_v, ref_u = R.momentum_accum_ref(v, u, g, mm, nn)
    np.testing.assert_allclose(np.asarray(got_v), np.asarray(ref_v), rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(got_u), np.asarray(ref_u), rtol=1e-5, atol=1e-6)


def test_momentum_accum_sgd_degenerates_to_plain_sum():
    v, g = rnd(1024, seed=31), rnd(1024, seed=32)
    u = jnp.zeros_like(v)
    zero = jnp.asarray([0.0], jnp.float32)
    got_v, got_u = K.momentum_accum(v, u, g, zero, zero)
    np.testing.assert_allclose(np.asarray(got_v), np.asarray(v + g), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(got_u), np.asarray(g))


def test_momentum_accum_velocity_recurrence():
    # two fused steps == the hand-rolled u recurrence
    v = jnp.zeros((512,), jnp.float32)
    u = jnp.zeros_like(v)
    g1, g2 = rnd(512, seed=41), rnd(512, seed=42)
    m = jnp.asarray([0.9], jnp.float32)
    z = jnp.asarray([0.0], jnp.float32)
    v, u = K.momentum_accum(v, u, g1, m, z)
    v, u = K.momentum_accum(v, u, g2, m, z)
    np.testing.assert_allclose(np.asarray(u), np.asarray(0.9 * g1 + g2), rtol=1e-5)
    np.testing.assert_allclose(
        np.asarray(v), np.asarray(g1 + 0.9 * g1 + g2), rtol=1e-5, atol=1e-6
    )
