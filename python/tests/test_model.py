"""L2 model tests: shapes, loss sanity, grad flow, trainability."""

import sys, os

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M


def init_params(specs, seed=0):
    rng = np.random.default_rng(seed)
    out = []
    for _, shape, init in specs:
        if init["kind"] == "zeros":
            arr = np.zeros(shape, np.float32)
        elif init["kind"] == "ones":
            arr = np.ones(shape, np.float32)
        elif init["kind"] == "he":
            arr = rng.standard_normal(shape).astype(np.float32) * np.sqrt(
                2.0 / init["fan_in"]
            )
        elif init["kind"] == "residual":
            arr = rng.standard_normal(shape).astype(np.float32) * (
                init["std"] / np.sqrt(2.0 * init["layers"])
            )
        else:
            arr = rng.standard_normal(shape).astype(np.float32) * init["std"]
        out.append(jnp.asarray(arr))
    return out


# ------------------------------------------------------------------- LM

CFG = M.LM_CONFIGS["lm_tiny"]


def lm_batch(cfg, seed=0):
    rng = np.random.default_rng(seed)
    toks = rng.integers(0, cfg["vocab"], (cfg["batch"], cfg["seq"]))
    tgts = rng.integers(0, cfg["vocab"], (cfg["batch"], cfg["seq"]))
    return jnp.asarray(toks, jnp.int32), jnp.asarray(tgts, jnp.int32)


def test_lm_loss_near_uniform_at_init():
    """With tiny init the LM should predict ~uniform: loss ~= ln(vocab)."""
    params = init_params(M.lm_param_specs(CFG))
    toks, tgts = lm_batch(CFG)
    loss = M.lm_loss(params, toks, tgts, CFG)
    assert abs(float(loss) - np.log(CFG["vocab"])) < 0.5


def test_lm_step_output_arity_and_shapes():
    specs = M.lm_param_specs(CFG)
    params = init_params(specs)
    toks, tgts = lm_batch(CFG)
    out = M.lm_step_fn(CFG)(*params, toks, tgts)
    assert len(out) == 1 + len(specs)
    assert out[0].shape == (1,)
    for (name, shape, _), g in zip(specs, out[1:]):
        assert g.shape == tuple(shape), name


def test_lm_grads_nonzero_everywhere():
    specs = M.lm_param_specs(CFG)
    params = init_params(specs)
    toks, tgts = lm_batch(CFG)
    out = M.lm_step_fn(CFG)(*params, toks, tgts)
    for (name, _, _), g in zip(specs, out[1:]):
        assert float(jnp.max(jnp.abs(g))) > 0, f"dead gradient for {name}"


def test_lm_few_sgd_steps_reduce_loss():
    """The step artifact's (loss, grads) must be usable for real training."""
    specs = M.lm_param_specs(CFG)
    params = init_params(specs)
    step = jax.jit(M.lm_step_fn(CFG))
    toks, tgts = lm_batch(CFG, seed=1)
    first = None
    for it in range(30):
        out = step(*params, toks, tgts)
        loss, grads = float(out[0][0]), out[1:]
        if first is None:
            first = loss
        params = [p - 0.5 * g for p, g in zip(params, grads)]
    assert loss < first - 0.3, f"no learning: {first} -> {loss}"


def test_lm_param_count_matches_formula():
    for name, cfg in M.LM_CONFIGS.items():
        v, d, l, s = cfg["vocab"], cfg["d_model"], cfg["n_layers"], cfg["seq"]
        # per layer: 4 attn mats (4d^2) + mlp (8d^2) + ln1/ln2 (4d) + b1 (4d) + b2 (d)
        expect = v * d + s * d + l * (12 * d * d + 9 * d) + 2 * d + d * v
        got = M.param_count(M.lm_param_specs(cfg))
        assert got == expect, name


def test_lm_eval_fn_matches_loss():
    specs = M.lm_param_specs(CFG)
    params = init_params(specs)
    toks, tgts = lm_batch(CFG)
    (l1,) = M.lm_logits_loss_fn(CFG)(*params, toks, tgts)
    l2 = M.lm_loss(params, toks, tgts, CFG)
    np.testing.assert_allclose(np.asarray(l1)[0], float(l2), rtol=1e-6)


# ------------------------------------------------------------------- MLP

MCFG = M.MLP_CONFIGS["mlp_tiny"]


def mlp_batch(cfg, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((cfg["batch"], cfg["in_dim"])).astype(np.float32)
    y = rng.integers(0, cfg["classes"], (cfg["batch"],))
    return jnp.asarray(x), jnp.asarray(y, jnp.int32)


def test_mlp_step_shapes():
    specs = M.mlp_param_specs(MCFG)
    params = init_params(specs)
    x, y = mlp_batch(MCFG)
    out = M.mlp_step_fn(MCFG)(*params, x, y)
    assert len(out) == 1 + len(specs)
    for (name, shape, _), g in zip(specs, out[1:]):
        assert g.shape == tuple(shape), name


def test_mlp_loss_at_init_near_log_classes():
    specs = M.mlp_param_specs(MCFG)
    params = init_params(specs)
    x, y = mlp_batch(MCFG)
    loss = M.mlp_loss(params, x, y, MCFG)
    assert abs(float(loss) - np.log(MCFG["classes"])) < 1.0


def test_mlp_learns_separable_data():
    specs = M.mlp_param_specs(MCFG)
    params = init_params(specs)
    rng = np.random.default_rng(3)
    # linearly separable clusters
    centers = rng.standard_normal((MCFG["classes"], MCFG["in_dim"])) * 3
    y = rng.integers(0, MCFG["classes"], (MCFG["batch"],))
    x = centers[y] + rng.standard_normal((MCFG["batch"], MCFG["in_dim"])) * 0.1
    x, y = jnp.asarray(x, jnp.float32), jnp.asarray(y, jnp.int32)
    step = jax.jit(M.mlp_step_fn(MCFG))
    for _ in range(60):
        out = step(*params, x, y)
        params = [p - 0.1 * g for p, g in zip(params, out[1:])]
    assert float(out[0][0]) < 0.2


def test_mlp_logits_fn_shape():
    specs = M.mlp_param_specs(MCFG)
    params = init_params(specs)
    x, _ = mlp_batch(MCFG)
    (logits,) = M.mlp_logits_fn(MCFG)(*params, x)
    assert logits.shape == (MCFG["batch"], MCFG["classes"])


@pytest.mark.parametrize("name", list(M.MLP_CONFIGS))
def test_mlp_spec_sizes_positive(name):
    cfg = M.MLP_CONFIGS[name]
    specs = M.mlp_param_specs(cfg)
    assert len(specs) == 2 * (cfg["depth"] + 1)
    assert M.param_count(specs) > 0
