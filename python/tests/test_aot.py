"""AOT pipeline tests: manifest integrity + HLO text loadability."""

import json
import os
import subprocess
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import pytest

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
MANIFEST = os.path.join(ART, "manifest.json")

pytestmark = pytest.mark.skipif(
    not os.path.exists(MANIFEST), reason="run `make artifacts` first"
)


def manifest():
    with open(MANIFEST) as f:
        return json.load(f)


def test_manifest_models_have_files():
    m = manifest()
    assert m["models"], "no models in manifest"
    for name, entry in m["models"].items():
        assert os.path.exists(os.path.join(ART, entry["file"])), name
        assert os.path.exists(os.path.join(ART, entry["eval_file"])), name


def test_manifest_param_specs_consistent():
    from compile import model as M

    m = manifest()
    for name, entry in m["models"].items():
        specs = (
            M.lm_param_specs(entry["config"])
            if entry["kind"] == "lm"
            else M.mlp_param_specs(entry["config"])
        )
        assert [p["name"] for p in entry["params"]] == [n for n, _, _ in specs]
        assert entry["param_count"] == M.param_count(specs)
        assert entry["outputs"][0] == "loss"
        assert entry["outputs"][1:] == [p["name"] for p in entry["params"]]


def test_manifest_compress_buckets_complete():
    m = manifest()
    for op in ["abs_stats", "threshold_count", "compress_mask", "sgd_update"]:
        assert op in m["compress_ops"]
        buckets = m["compress_ops"][op]["buckets"]
        assert set(map(int, buckets)) == set(m["buckets"])
        for f in buckets.values():
            assert os.path.exists(os.path.join(ART, f)), f


def test_hlo_text_is_parseable_hlo():
    """Every artifact must start with an HloModule header (text format)."""
    m = manifest()
    files = [e["file"] for e in m["models"].values()]
    for op in m["compress_ops"].values():
        files += list(op["buckets"].values())
    for f in files:
        with open(os.path.join(ART, f)) as fh:
            head = fh.read(64)
        assert head.startswith("HloModule"), f


def test_hlo_entry_has_expected_arity():
    """lm_tiny step: n_params + 2 inputs, 1 + n_params outputs (tuple)."""
    m = manifest()
    entry = m["models"].get("lm_tiny")
    if entry is None:
        pytest.skip("lm_tiny not built")
    n = len(entry["params"])
    with open(os.path.join(ART, entry["file"])) as fh:
        text = fh.read()
    # count parameter(k) declarations in ENTRY computation
    import re

    entry_sig = re.search(r"ENTRY .*?\{(.*?)\n\}", text, re.S)
    assert entry_sig is not None
    params = re.findall(r"parameter\((\d+)\)", entry_sig.group(1))
    assert len(params) == n + 2


def test_aot_is_incremental():
    """Second run with unchanged sources must skip (prints 'up to date')."""
    env = dict(os.environ)
    out = subprocess.run(
        [sys.executable, "-m", "compile.aot"],
        cwd=os.path.join(os.path.dirname(__file__), ".."),
        capture_output=True,
        text=True,
        env=env,
        timeout=600,
    )
    assert out.returncode == 0, out.stderr
    assert "up to date" in out.stdout, out.stdout
